//! Checkpointing: binary save/load of parameters with optional block-wise
//! int8 or FP8-E4M3 compression (paper §S11: optimizer/checkpoint states
//! tolerate 8-bit storage).
//!
//! Format (little-endian):
//!   magic "CHKP1\0\0\0" | codec u32 | n_tensors u32
//!   per tensor: ndim u32 | dims u32* | payload
//!     codec 0 (f32): n*4 bytes raw
//!     codec 1 (int8): block u32 | n_blocks u32 | scales f32* | data i8*
//!     codec 2 (fp8-e4m3 sim): stored as f32 grid values after round-trip
//!       (half the information, full width on disk — a fidelity study, not
//!       a size win; int8 is the size win)
//!
//! Train-state format (`save_train_state`/`load_train_state`) — the
//! resume-equals-continuous contract (DESIGN.md §12): parameters are
//! always raw f32 (bit-exact) and the optimizer snapshot is stored *in
//! its own codec* — int8 slots serialize their quantized bytes, scales
//! and compensations verbatim, so a resumed run decodes the identical
//! moments the continuous run holds:
//!   magic "CHKS1\0\0\0" | step u64 | n_params u32
//!   per param: ndim u32 | dims u32* | n*4 bytes raw f32
//!   optim codec u32 (0 = fp32, 1 = int8) | n_slot_pairs u32
//!     fp32: per pair: len u32 | m f32* | v f32*
//!     int8: per pair: per slot (m then v):
//!       n u32 | block u32 | n_blocks u32 | data i8* | scales f32* | comp f32*

use crate::quant::{
    fp8_decode, int8_dequantize, int8_quantize, Fp8Format, Int8Blocks, Int8Slot, OptimSnapshot,
};
use crate::runtime::HostTensor;
use anyhow::{anyhow, bail, ensure, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CHKP1\0\0\0";
const STATE_MAGIC: &[u8; 8] = b"CHKS1\0\0\0";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    F32 = 0,
    Int8 = 1,
    Fp8E4m3 = 2,
}

impl Codec {
    fn from_u32(x: u32) -> Result<Codec> {
        Ok(match x {
            0 => Codec::F32,
            1 => Codec::Int8,
            2 => Codec::Fp8E4m3,
            _ => bail!("unknown codec {x}"),
        })
    }
}

const INT8_BLOCK: usize = 128;

pub fn save(path: impl AsRef<Path>, tensors: &[HostTensor], codec: Codec) -> Result<()> {
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(codec as u32).to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        let data = t.as_f32().map_err(|_| anyhow!("only f32 tensors checkpoint"))?;
        let shape = t.shape();
        w.write_all(&(shape.len() as u32).to_le_bytes())?;
        for &d in shape {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        match codec {
            Codec::F32 => {
                for &x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            Codec::Int8 => {
                let q = int8_quantize(data, INT8_BLOCK);
                w.write_all(&(q.block as u32).to_le_bytes())?;
                w.write_all(&(q.scales.len() as u32).to_le_bytes())?;
                for &s in &q.scales {
                    w.write_all(&s.to_le_bytes())?;
                }
                let bytes: Vec<u8> = q.data.iter().map(|&b| b as u8).collect();
                w.write_all(&bytes)?;
            }
            Codec::Fp8E4m3 => {
                let q = fp8_decode(data, Fp8Format::E4M3);
                for &x in &q {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<HostTensor>> {
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad checkpoint magic");
    }
    let codec = Codec::from_u32(read_u32(&mut r)?)?;
    let n_tensors = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let ndim = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let n: usize = shape.iter().product::<usize>().max(1);
        let data = match codec {
            Codec::F32 | Codec::Fp8E4m3 => read_f32s(&mut r, n)?,
            Codec::Int8 => {
                let block = read_u32(&mut r)? as usize;
                let n_blocks = read_u32(&mut r)? as usize;
                let scales = read_f32s(&mut r, n_blocks)?;
                let mut bytes = vec![0u8; n_blocks * block];
                r.read_exact(&mut bytes)?;
                let q = Int8Blocks {
                    data: bytes.into_iter().map(|b| b as i8).collect(),
                    scales,
                    block,
                    n,
                };
                int8_dequantize(&q)
            }
        };
        out.push(HostTensor::f32(data, shape));
    }
    Ok(out)
}

/// Everything a training run needs to resume exactly where it stopped.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    /// Last completed optimizer step.
    pub step: u64,
    /// Full parameter set, dense f32 (quantized base weights are
    /// dequantized by `Backend::state_params` before they get here; the
    /// values sit on the codec grid, so requantizing on load is lossless).
    pub params: Vec<HostTensor>,
    /// Optimizer slots in their native codec.
    pub optim: OptimSnapshot,
}

/// Serialize a full train state (see the module-level format notes).
pub fn save_train_state(path: impl AsRef<Path>, ts: &TrainState) -> Result<()> {
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(STATE_MAGIC)?;
    w.write_all(&ts.step.to_le_bytes())?;
    w.write_all(&(ts.params.len() as u32).to_le_bytes())?;
    for t in &ts.params {
        let data = t.as_f32().map_err(|_| anyhow!("only f32 tensors checkpoint"))?;
        let shape = t.shape();
        w.write_all(&(shape.len() as u32).to_le_bytes())?;
        for &d in shape {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        for &x in data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    match &ts.optim {
        OptimSnapshot::Fp32 { m, v } => {
            w.write_all(&0u32.to_le_bytes())?;
            ensure!(m.len() == v.len(), "m/v slot count mismatch");
            w.write_all(&(m.len() as u32).to_le_bytes())?;
            for (sm, sv) in m.iter().zip(v) {
                ensure!(sm.len() == sv.len(), "m/v slot length mismatch");
                w.write_all(&(sm.len() as u32).to_le_bytes())?;
                for &x in sm {
                    w.write_all(&x.to_le_bytes())?;
                }
                for &x in sv {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
        OptimSnapshot::Int8 { m, v } => {
            w.write_all(&1u32.to_le_bytes())?;
            ensure!(m.len() == v.len(), "m/v slot count mismatch");
            w.write_all(&(m.len() as u32).to_le_bytes())?;
            for (sm, sv) in m.iter().zip(v) {
                for s in [sm, sv] {
                    w.write_all(&(s.q.n as u32).to_le_bytes())?;
                    w.write_all(&(s.q.block as u32).to_le_bytes())?;
                    w.write_all(&(s.q.scales.len() as u32).to_le_bytes())?;
                    ensure!(s.comp.len() == s.q.scales.len(), "comp/scales length mismatch");
                    let bytes: Vec<u8> = s.q.data.iter().map(|&b| b as u8).collect();
                    w.write_all(&bytes)?;
                    for &x in &s.q.scales {
                        w.write_all(&x.to_le_bytes())?;
                    }
                    for &x in &s.comp {
                        w.write_all(&x.to_le_bytes())?;
                    }
                }
            }
        }
    }
    Ok(())
}

/// Load a train state saved by [`save_train_state`]. Bitwise faithful:
/// f32 payloads and int8 slot bytes come back exactly as written.
pub fn load_train_state(path: impl AsRef<Path>) -> Result<TrainState> {
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != STATE_MAGIC {
        bail!("bad train-state magic (expected a CHKS1 file saved by save_train_state)");
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let step = u64::from_le_bytes(b8);
    let n_params = read_u32(&mut r)? as usize;
    let mut params = Vec::with_capacity(n_params);
    for _ in 0..n_params {
        let ndim = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let n: usize = shape.iter().product::<usize>().max(1);
        params.push(HostTensor::f32(read_f32s(&mut r, n)?, shape));
    }
    let codec = read_u32(&mut r)?;
    let n_slots = read_u32(&mut r)? as usize;
    let optim = match codec {
        0 => {
            let (mut m, mut v) = (Vec::with_capacity(n_slots), Vec::with_capacity(n_slots));
            for _ in 0..n_slots {
                let len = read_u32(&mut r)? as usize;
                m.push(read_f32s(&mut r, len)?);
                v.push(read_f32s(&mut r, len)?);
            }
            OptimSnapshot::Fp32 { m, v }
        }
        1 => {
            let (mut m, mut v) = (Vec::with_capacity(n_slots), Vec::with_capacity(n_slots));
            for _ in 0..n_slots {
                for dst in [&mut m, &mut v] {
                    let n = read_u32(&mut r)? as usize;
                    let block = read_u32(&mut r)? as usize;
                    let n_blocks = read_u32(&mut r)? as usize;
                    let mut bytes = vec![0u8; n];
                    r.read_exact(&mut bytes)?;
                    let scales = read_f32s(&mut r, n_blocks)?;
                    let comp = read_f32s(&mut r, n_blocks)?;
                    dst.push(Int8Slot {
                        q: Int8Blocks {
                            data: bytes.into_iter().map(|b| b as i8).collect(),
                            scales,
                            block,
                            n,
                        },
                        comp,
                    });
                }
            }
            OptimSnapshot::Int8 { m, v }
        }
        other => bail!("unknown optimizer-state codec {other} in train-state checkpoint"),
    };
    Ok(TrainState { step, params, optim })
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tensors() -> Vec<HostTensor> {
        let mut rng = Rng::new(10);
        vec![
            HostTensor::f32((0..64).map(|_| rng.normal() as f32).collect(), vec![8, 8]),
            HostTensor::f32((0..10).map(|_| rng.normal() as f32).collect(), vec![10]),
            HostTensor::scalar_f32(3.25),
        ]
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("chronicals_ckpt_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn f32_roundtrip_exact() {
        let ts = tensors();
        let p = tmp("f32.ckpt");
        save(&p, &ts, Codec::F32).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(ts, back);
    }

    #[test]
    fn int8_roundtrip_within_bound() {
        let ts = tensors();
        let p = tmp("int8.ckpt");
        save(&p, &ts, Codec::Int8).unwrap();
        let back = load(&p).unwrap();
        for (a, b) in ts.iter().zip(&back) {
            assert_eq!(a.shape(), b.shape());
            let (xa, xb) = (a.as_f32().unwrap(), b.as_f32().unwrap());
            let amax = xa.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            for (u, v) in xa.iter().zip(xb) {
                assert!((u - v).abs() <= amax / 127.0 * 0.5 + 1e-7);
            }
        }
    }

    #[test]
    fn int8_file_smaller_than_f32() {
        let mut rng = Rng::new(11);
        let big = vec![HostTensor::f32(
            (0..100_000).map(|_| rng.normal() as f32).collect(),
            vec![100_000],
        )];
        let pf = tmp("big_f32.ckpt");
        let pq = tmp("big_int8.ckpt");
        save(&pf, &big, Codec::F32).unwrap();
        save(&pq, &big, Codec::Int8).unwrap();
        let sf = std::fs::metadata(&pf).unwrap().len();
        let sq = std::fs::metadata(&pq).unwrap().len();
        assert!(sf as f64 / sq as f64 > 3.5, "{sf} vs {sq}");
    }

    #[test]
    fn fp8_roundtrip_on_grid() {
        let ts = tensors();
        let p = tmp("fp8.ckpt");
        save(&p, &ts, Codec::Fp8E4m3).unwrap();
        let back = load(&p).unwrap();
        for (a, b) in ts.iter().zip(&back) {
            for (u, v) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
                if u.abs() >= 2.0f32.powi(-6) {
                    // normal range: half-ulp relative bound (3 mantissa bits)
                    assert!(((u - v) / u).abs() <= 0.0625 + 1e-6, "{u} vs {v}");
                } else {
                    // subnormal range: absolute bound of half the quantum
                    assert!((u - v).abs() <= 2.0f32.powi(-10) + 1e-9, "{u} vs {v}");
                }
            }
        }
    }

    #[test]
    fn corrupted_magic_rejected() {
        let p = tmp("bad.ckpt");
        std::fs::write(&p, b"NOTACKPT________").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn train_state_fp32_roundtrips_bitwise() {
        let mut rng = Rng::new(21);
        let ts = TrainState {
            step: 1234,
            params: tensors(),
            optim: OptimSnapshot::Fp32 {
                m: vec![(0..64).map(|_| rng.normal() as f32).collect(), vec![0.5; 10]],
                v: vec![(0..64).map(|_| rng.normal() as f32 * 1e-4).collect(), vec![0.0; 10]],
            },
        };
        let p = tmp("train_fp32.ckpt");
        save_train_state(&p, &ts).unwrap();
        let back = load_train_state(&p).unwrap();
        assert_eq!(ts, back); // PartialEq on f32 vecs == bitwise here
    }

    #[test]
    fn train_state_int8_roundtrips_bitwise() {
        let mut rng = Rng::new(22);
        let mk = |n: usize, seed_scale: f32| {
            let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * seed_scale).collect();
            let mut s = Int8Slot::zeros(n);
            s.encode_from(&x);
            s
        };
        let ts = TrainState {
            step: 7,
            params: tensors(),
            // ragged lengths exercise the unpadded slot payloads
            optim: OptimSnapshot::Int8 {
                m: vec![mk(300, 0.01), mk(10, 1.0)],
                v: vec![mk(300, 1e-4), mk(10, 1e-6)],
            },
        };
        let p = tmp("train_int8.ckpt");
        save_train_state(&p, &ts).unwrap();
        let back = load_train_state(&p).unwrap();
        assert_eq!(ts, back, "int8 slot bytes/scales/comps must roundtrip verbatim");
    }

    #[test]
    fn train_state_rejects_param_checkpoint_magic() {
        let ts = tensors();
        let p = tmp("wrong_kind.ckpt");
        save(&p, &ts, Codec::F32).unwrap();
        let err = load_train_state(&p).unwrap_err().to_string();
        assert!(err.contains("CHKS1"), "unhelpful error: {err}");
    }
}
