//! Checkpointing: binary save/load of parameters with optional block-wise
//! int8 or FP8-E4M3 compression (paper §S11: optimizer/checkpoint states
//! tolerate 8-bit storage).
//!
//! Format (little-endian):
//!   magic "CHKP1\0\0\0" | codec u32 | n_tensors u32
//!   per tensor: ndim u32 | dims u32* | payload
//!     codec 0 (f32): n*4 bytes raw
//!     codec 1 (int8): block u32 | n_blocks u32 | scales f32* | data i8*
//!     codec 2 (fp8-e4m3 sim): stored as f32 grid values after round-trip
//!       (half the information, full width on disk — a fidelity study, not
//!       a size win; int8 is the size win)

use crate::quant::{fp8_decode, int8_dequantize, int8_quantize, Fp8Format, Int8Blocks};
use crate::runtime::HostTensor;
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CHKP1\0\0\0";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    F32 = 0,
    Int8 = 1,
    Fp8E4m3 = 2,
}

impl Codec {
    fn from_u32(x: u32) -> Result<Codec> {
        Ok(match x {
            0 => Codec::F32,
            1 => Codec::Int8,
            2 => Codec::Fp8E4m3,
            _ => bail!("unknown codec {x}"),
        })
    }
}

const INT8_BLOCK: usize = 128;

pub fn save(path: impl AsRef<Path>, tensors: &[HostTensor], codec: Codec) -> Result<()> {
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(codec as u32).to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for t in tensors {
        let data = t.as_f32().map_err(|_| anyhow!("only f32 tensors checkpoint"))?;
        let shape = t.shape();
        w.write_all(&(shape.len() as u32).to_le_bytes())?;
        for &d in shape {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        match codec {
            Codec::F32 => {
                for &x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            Codec::Int8 => {
                let q = int8_quantize(data, INT8_BLOCK);
                w.write_all(&(q.block as u32).to_le_bytes())?;
                w.write_all(&(q.scales.len() as u32).to_le_bytes())?;
                for &s in &q.scales {
                    w.write_all(&s.to_le_bytes())?;
                }
                let bytes: Vec<u8> = q.data.iter().map(|&b| b as u8).collect();
                w.write_all(&bytes)?;
            }
            Codec::Fp8E4m3 => {
                let q = fp8_decode(data, Fp8Format::E4M3);
                for &x in &q {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<HostTensor>> {
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad checkpoint magic");
    }
    let codec = Codec::from_u32(read_u32(&mut r)?)?;
    let n_tensors = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let ndim = read_u32(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let n: usize = shape.iter().product::<usize>().max(1);
        let data = match codec {
            Codec::F32 | Codec::Fp8E4m3 => read_f32s(&mut r, n)?,
            Codec::Int8 => {
                let block = read_u32(&mut r)? as usize;
                let n_blocks = read_u32(&mut r)? as usize;
                let scales = read_f32s(&mut r, n_blocks)?;
                let mut bytes = vec![0u8; n_blocks * block];
                r.read_exact(&mut bytes)?;
                let q = Int8Blocks {
                    data: bytes.into_iter().map(|b| b as i8).collect(),
                    scales,
                    block,
                    n,
                };
                int8_dequantize(&q)
            }
        };
        out.push(HostTensor::f32(data, shape));
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tensors() -> Vec<HostTensor> {
        let mut rng = Rng::new(10);
        vec![
            HostTensor::f32((0..64).map(|_| rng.normal() as f32).collect(), vec![8, 8]),
            HostTensor::f32((0..10).map(|_| rng.normal() as f32).collect(), vec![10]),
            HostTensor::scalar_f32(3.25),
        ]
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("chronicals_ckpt_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn f32_roundtrip_exact() {
        let ts = tensors();
        let p = tmp("f32.ckpt");
        save(&p, &ts, Codec::F32).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(ts, back);
    }

    #[test]
    fn int8_roundtrip_within_bound() {
        let ts = tensors();
        let p = tmp("int8.ckpt");
        save(&p, &ts, Codec::Int8).unwrap();
        let back = load(&p).unwrap();
        for (a, b) in ts.iter().zip(&back) {
            assert_eq!(a.shape(), b.shape());
            let (xa, xb) = (a.as_f32().unwrap(), b.as_f32().unwrap());
            let amax = xa.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            for (u, v) in xa.iter().zip(xb) {
                assert!((u - v).abs() <= amax / 127.0 * 0.5 + 1e-7);
            }
        }
    }

    #[test]
    fn int8_file_smaller_than_f32() {
        let mut rng = Rng::new(11);
        let big = vec![HostTensor::f32(
            (0..100_000).map(|_| rng.normal() as f32).collect(),
            vec![100_000],
        )];
        let pf = tmp("big_f32.ckpt");
        let pq = tmp("big_int8.ckpt");
        save(&pf, &big, Codec::F32).unwrap();
        save(&pq, &big, Codec::Int8).unwrap();
        let sf = std::fs::metadata(&pf).unwrap().len();
        let sq = std::fs::metadata(&pq).unwrap().len();
        assert!(sf as f64 / sq as f64 > 3.5, "{sf} vs {sq}");
    }

    #[test]
    fn fp8_roundtrip_on_grid() {
        let ts = tensors();
        let p = tmp("fp8.ckpt");
        save(&p, &ts, Codec::Fp8E4m3).unwrap();
        let back = load(&p).unwrap();
        for (a, b) in ts.iter().zip(&back) {
            for (u, v) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
                if u.abs() >= 2.0f32.powi(-6) {
                    // normal range: half-ulp relative bound (3 mantissa bits)
                    assert!(((u - v) / u).abs() <= 0.0625 + 1e-6, "{u} vs {v}");
                } else {
                    // subnormal range: absolute bound of half the quantum
                    assert!((u - v).abs() <= 2.0f32.powi(-10) + 1e-9, "{u} vs {v}");
                }
            }
        }
    }

    #[test]
    fn corrupted_magic_rejected() {
        let p = tmp("bad.ckpt");
        std::fs::write(&p, b"NOTACKPT________").unwrap();
        assert!(load(&p).is_err());
    }
}
