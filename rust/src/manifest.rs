//! The AOT artifact manifest: what `python/compile/aot.py` emitted, in a
//! form the runtime and trainer can wire up blindly.
//!
//! The Rust↔HLO calling convention is positional; the manifest records the
//! exact ordered input/output layout of every executable so the trainer
//! never guesses (see `python/compile/aot.py`).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Element type of a tensor input (only what the artifacts use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(anyhow!("unsupported dtype '{other}'")),
        }
    }
    pub fn size_bytes(self) -> usize {
        4
    }
}

/// What an input slot is for — drives the trainer's wiring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Trainable parameter (updated by the step, fed back each step).
    Param,
    /// Frozen parameter (fed each step, never updated).
    Frozen,
    /// Optimizer state slot (updated by the step).
    Opt,
    /// Per-step batch tensor (tokens/targets/seg_ids/pos_ids).
    Batch,
    /// Per-step scalar (step counter, lr, lr_b, seed).
    Scalar,
}

impl Role {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "param" => Role::Param,
            "frozen" => Role::Frozen,
            "opt" => Role::Opt,
            "batch" => Role::Batch,
            "scalar" => Role::Scalar,
            other => return Err(anyhow!("unknown role '{other}'")),
        })
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub role: Role,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Step-config echo from the Python side (what the variant lowers).
#[derive(Debug, Clone, Default)]
pub struct StepConfigEcho {
    pub attention: String,
    pub kernels: String,
    pub loss: String,
    pub optimizer: String,
    pub broken: bool,
    pub lora_rank: usize,
    pub lora_alpha: usize,
}

/// Model-config echo (for MFU / memory estimation).
#[derive(Debug, Clone, Default)]
pub struct ModelConfigEcho {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
}

#[derive(Debug, Clone)]
pub struct ExecutableSpec {
    pub name: String,
    pub file: String,
    pub kind: String, // train | init | eval | kernel
    pub variant: String,
    pub family: String,
    pub batch: usize,
    pub seq: usize,
    pub n_trainable: usize,
    pub n_frozen: usize,
    pub n_slots: usize,
    pub param_count: u64,
    pub trainable_param_count: u64,
    pub step_config: StepConfigEcho,
    pub model_config: ModelConfigEcho,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
}

impl ExecutableSpec {
    /// Number of leading inputs that form the persistent training state
    /// (params + frozen + opt slots), in order.
    pub fn n_state_inputs(&self) -> usize {
        self.n_trainable + self.n_frozen + self.n_slots * self.n_trainable
    }

    /// Number of leading outputs that refresh the state (new trainable
    /// params + new opt slots). Frozen params are not re-emitted.
    pub fn n_state_outputs(&self) -> usize {
        self.n_trainable + self.n_slots * self.n_trainable
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub profile: String,
    pub dir: PathBuf,
    pub executables: Vec<ExecutableSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let profile = j
            .field("profile")?
            .as_str()
            .unwrap_or("unknown")
            .to_string();
        let mut executables = Vec::new();
        for e in j.field("executables")?.as_arr().unwrap_or(&[]) {
            executables.push(parse_exec(e)?);
        }
        Ok(Manifest { profile, dir, executables })
    }

    pub fn get(&self, name: &str) -> Result<&ExecutableSpec> {
        self.executables
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "executable '{name}' not in manifest (have: {})",
                    self.executables
                        .iter()
                        .map(|e| e.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    pub fn hlo_path(&self, spec: &ExecutableSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

fn parse_exec(e: &Json) -> Result<ExecutableSpec> {
    let get_usize = |k: &str| e.field(k).ok().and_then(|v| v.as_usize()).unwrap_or(0);
    let get_str = |k: &str| {
        e.field(k)
            .ok()
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string()
    };
    let mut inputs = Vec::new();
    for i in e.field("inputs")?.as_arr().unwrap_or(&[]) {
        let shape = i
            .field("shape")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        inputs.push(TensorSpec {
            name: i.field("name")?.as_str().unwrap_or("").to_string(),
            shape,
            dtype: DType::parse(i.field("dtype")?.as_str().unwrap_or("float32"))?,
            role: Role::parse(i.field("role")?.as_str().unwrap_or("batch"))?,
        });
    }
    let outputs = e
        .field("outputs")
        .ok()
        .and_then(|v| v.as_arr())
        .unwrap_or(&[])
        .iter()
        .filter_map(|v| v.as_str().map(str::to_string))
        .collect();

    let sc = e.field("step_config").ok();
    let step_config = sc
        .map(|s| StepConfigEcho {
            attention: s.field("attention").ok().and_then(|v| v.as_str()).unwrap_or("").into(),
            kernels: s.field("kernels").ok().and_then(|v| v.as_str()).unwrap_or("").into(),
            loss: s.field("loss").ok().and_then(|v| v.as_str()).unwrap_or("").into(),
            optimizer: s.field("optimizer").ok().and_then(|v| v.as_str()).unwrap_or("").into(),
            broken: s.field("broken").ok().and_then(|v| v.as_bool()).unwrap_or(false),
            lora_rank: s.field("lora_rank").ok().and_then(|v| v.as_usize()).unwrap_or(0),
            lora_alpha: s.field("lora_alpha").ok().and_then(|v| v.as_usize()).unwrap_or(0),
        })
        .unwrap_or_default();

    let mc = e.field("model_config").ok();
    let model_config = mc
        .map(|m| ModelConfigEcho {
            vocab: m.field("vocab").ok().and_then(|v| v.as_usize()).unwrap_or(0),
            d_model: m.field("d_model").ok().and_then(|v| v.as_usize()).unwrap_or(0),
            n_layers: m.field("n_layers").ok().and_then(|v| v.as_usize()).unwrap_or(0),
            n_heads: m.field("n_heads").ok().and_then(|v| v.as_usize()).unwrap_or(0),
            n_kv_heads: m.field("n_kv_heads").ok().and_then(|v| v.as_usize()).unwrap_or(0),
            d_ff: m.field("d_ff").ok().and_then(|v| v.as_usize()).unwrap_or(0),
        })
        .unwrap_or_default();

    Ok(ExecutableSpec {
        name: get_str("name"),
        file: get_str("file"),
        kind: get_str("kind"),
        variant: get_str("variant"),
        family: get_str("family"),
        batch: get_usize("batch"),
        seq: get_usize("seq"),
        n_trainable: get_usize("n_trainable"),
        n_frozen: get_usize("n_frozen"),
        n_slots: get_usize("n_slots"),
        param_count: get_usize("param_count") as u64,
        trainable_param_count: get_usize("trainable_param_count") as u64,
        step_config,
        model_config,
        inputs,
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "profile": "test",
      "executables": [
        {"name": "train_step_x", "file": "train_step_x.hlo.txt", "kind": "train",
         "variant": "x", "family": "full", "batch": 2, "seq": 64,
         "n_trainable": 3, "n_frozen": 0, "n_slots": 2,
         "param_count": 100, "trainable_param_count": 100,
         "step_config": {"attention": "flash_scan", "kernels": "jnp",
                          "loss": "cce_scan", "optimizer": "adamw",
                          "broken": false, "lora_rank": 32, "lora_alpha": 64},
         "model_config": {"vocab": 512, "d_model": 64, "n_layers": 2,
                           "n_heads": 4, "n_kv_heads": 2, "d_ff": 128},
         "inputs": [
            {"name": "embed", "shape": [512, 64], "dtype": "float32", "role": "param"},
            {"name": "tokens", "shape": [2, 64], "dtype": "int32", "role": "batch"},
            {"name": "lr", "shape": [], "dtype": "float32", "role": "scalar"}
         ],
         "outputs": ["param.embed", "loss"]}
      ]
    }"#;

    fn sample_manifest() -> Manifest {
        let dir = std::env::temp_dir().join("chronicals_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        Manifest::load(&dir).unwrap()
    }

    #[test]
    fn loads_and_indexes() {
        let m = sample_manifest();
        assert_eq!(m.profile, "test");
        let e = m.get("train_step_x").unwrap();
        assert_eq!(e.batch, 2);
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].role, Role::Param);
        assert_eq!(e.inputs[1].dtype, DType::I32);
        assert_eq!(e.inputs[2].shape.len(), 0);
        assert_eq!(e.inputs[2].elements(), 1);
        assert_eq!(e.n_state_inputs(), 3 + 0 + 6);
        assert_eq!(e.n_state_outputs(), 3 + 6);
    }

    #[test]
    fn unknown_executable_is_error() {
        let m = sample_manifest();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn dtype_parse() {
        assert!(DType::parse("float32").is_ok());
        assert!(DType::parse("bfloat16").is_err());
    }
}
