//! The one seam where typed tasks meet manifest executable names.
//!
//! Every `train_step_*` / `init_*` string in the crate is constructed (or
//! recognized) here and nowhere else: callers hold a typed
//! [`Task`](super::Task) and receive a [`Resolved`] wiring — executable
//! names plus the manifest spec — so the manifest string zoo never leaks
//! into the harness, the CLI or the benches. The geometry-matching init
//! fallback that used to live in `harness::resolve_init` lives here too.

use super::Task;
use crate::manifest::{ExecutableSpec, Manifest};
use anyhow::{anyhow, bail, Context, Result};

/// A task resolved against a concrete backend manifest: the exact train and
/// init executables to run, the executable spec (geometry, param counts,
/// step config echo) and the effective LoRA+ ratio for the lr schedule.
#[derive(Debug, Clone)]
pub struct Resolved {
    pub train: String,
    pub init: String,
    pub spec: ExecutableSpec,
    pub lora_plus_ratio: f64,
}

/// The e2e-scale train executable (PJRT artifact set only; the CPU
/// substrate backends don't register it). Used by the `e2e` preset; it has
/// no typed task of its own, so runs lower through [`Task::Custom`].
pub const E2E_EXECUTABLE: &str = "train_step_e2e";

/// The manifest executable name a task runs. This is the only place in the
/// crate that *builds* `train_step_*` names.
pub fn train_executable(task: &Task) -> String {
    match task {
        Task::FullFinetune => "train_step_chronicals".into(),
        Task::Lora { .. } | Task::LoraPlus { .. } => "train_step_lora".into(),
        Task::AblateNaive => "train_step_ablate_naive".into(),
        Task::AblateFlash => "train_step_ablate_flash".into(),
        Task::AblateCompiled => "train_step_ablate_compiled".into(),
        Task::AblateLiger => "train_step_ablate_liger".into(),
        Task::LoraNaive => "train_step_lora_naive".into(),
        Task::LoraBroken => "train_step_lora_broken".into(),
        Task::Custom { executable, .. } => executable.clone(),
    }
}

/// Derive the canonical `init_<variant>` name from a train executable name.
pub fn derive_init_name(train: &str) -> String {
    train
        .strip_prefix("train_step_")
        .map(|v| format!("init_{v}"))
        .unwrap_or_else(|| "init_chronicals".into())
}

/// Recognize a legacy executable-name string as a typed task (the
/// `RunConfig` → `SessionSpec` lowering direction). Unknown names — and any
/// combination that the typed variants cannot express, like an explicit
/// init override — become [`Task::Custom`], the escape hatch.
pub fn task_from_executable(
    executable: &str,
    init: Option<&str>,
    lora_plus_ratio: f64,
) -> Task {
    if init.is_some() {
        return Task::Custom {
            executable: executable.to_string(),
            init: init.map(str::to_string),
            lora_plus_ratio,
        };
    }
    let ratio_is_off = (lora_plus_ratio - 1.0).abs() < 1e-12;
    match executable {
        "train_step_chronicals" if ratio_is_off => Task::FullFinetune,
        "train_step_lora" if ratio_is_off => Task::Lora { rank: None },
        "train_step_lora" => Task::LoraPlus { rank: None, ratio: lora_plus_ratio },
        "train_step_ablate_naive" if ratio_is_off => Task::AblateNaive,
        "train_step_ablate_flash" if ratio_is_off => Task::AblateFlash,
        "train_step_ablate_compiled" if ratio_is_off => Task::AblateCompiled,
        "train_step_ablate_liger" if ratio_is_off => Task::AblateLiger,
        "train_step_lora_naive" if ratio_is_off => Task::LoraNaive,
        "train_step_lora_broken" if ratio_is_off => Task::LoraBroken,
        other => Task::Custom {
            executable: other.to_string(),
            init: None,
            lora_plus_ratio,
        },
    }
}

/// Resolve a task against a backend manifest: pick the train executable,
/// validate what the backend actually provides (kind, LoRA rank), and find
/// a usable init executable.
pub fn resolve(manifest: &Manifest, task: &Task) -> Result<Resolved> {
    let train = train_executable(task);
    let spec = manifest
        .get(&train)
        .with_context(|| format!("resolving {task} on this backend"))?
        .clone();
    if spec.kind != "train" {
        bail!("{task} resolves to '{train}', which is not a train executable (kind = {})", spec.kind);
    }
    if let Task::Lora { rank: Some(r) } | Task::LoraPlus { rank: Some(r), .. } = task {
        if spec.step_config.lora_rank != *r {
            bail!(
                "{task} requests LoRA rank {r}, but '{train}' on this backend is compiled \
                 with rank {} — drop the rank to accept the backend default",
                spec.step_config.lora_rank
            );
        }
    }
    let preferred = match task {
        Task::Custom { init: Some(i), .. } => i.clone(),
        _ => derive_init_name(&train),
    };
    let init = resolve_init(manifest, &train, &preferred)?;
    Ok(Resolved { train, init, spec, lora_plus_ratio: task.lora_plus_ratio() })
}

/// Find the forward-only eval executable for a train executable: the
/// canonical `eval_<variant>` when the backend registers it, else any
/// `kind == "eval"` executable of the same family and batch geometry
/// (ablation aliases and broken variants share their family's eval, just
/// like they share its init).
pub fn resolve_eval(manifest: &Manifest, train_name: &str) -> Result<String> {
    let preferred = train_name
        .strip_prefix("train_step_")
        .map(|v| format!("eval_{v}"))
        .unwrap_or_else(|| "eval_chronicals".into());
    if let Ok(e) = manifest.get(&preferred) {
        if e.kind == "eval" {
            return Ok(preferred);
        }
    }
    let train = manifest.get(train_name)?;
    for e in &manifest.executables {
        if e.kind == "eval"
            && e.family == train.family
            && e.batch == train.batch
            && e.seq == train.seq
        {
            return Ok(e.name.clone());
        }
    }
    Err(anyhow!(
        "no eval executable for {train_name} on this backend — \
         held-out eval needs a forward-only executable of the same family"
    ))
}

/// Find a usable init executable: the requested one, else the canonical
/// init for the same family and model/batch geometry (ablation aliases and
/// broken variants have no init of their own).
pub fn resolve_init(manifest: &Manifest, train_name: &str, preferred: &str) -> Result<String> {
    if manifest.get(preferred).is_ok() {
        return Ok(preferred.to_string());
    }
    let train = manifest.get(train_name)?;
    for e in &manifest.executables {
        if e.kind == "init"
            && e.family == train.family
            && e.n_trainable == train.n_trainable
            && e.n_frozen == train.n_frozen
            // same tensor count is not enough — shapes must match too
            && e.param_count == train.param_count
        {
            return Ok(e.name.clone());
        }
    }
    Err(anyhow!("no init executable for {train_name}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;
    use crate::backend::Backend;

    #[test]
    fn typed_tasks_resolve_on_the_reference_backend() {
        let be = CpuBackend::new();
        for task in [
            Task::FullFinetune,
            Task::Lora { rank: None },
            Task::LoraPlus { rank: None, ratio: 16.0 },
            Task::AblateNaive,
            Task::AblateFlash,
            Task::AblateCompiled,
            Task::AblateLiger,
            Task::LoraNaive,
            Task::LoraBroken,
        ] {
            let r = resolve(be.manifest(), &task).unwrap();
            assert_eq!(r.spec.kind, "train", "{task}");
            assert!(!r.init.is_empty(), "{task}");
        }
    }

    #[test]
    fn ablation_and_broken_variants_fall_back_to_family_init() {
        let be = CpuBackend::new();
        let r = resolve(be.manifest(), &Task::AblateNaive).unwrap();
        assert_eq!(r.init, "init_chronicals");
        let r = resolve(be.manifest(), &Task::LoraBroken).unwrap();
        assert_eq!(r.init, "init_lora");
    }

    #[test]
    fn eval_resolves_for_every_train_task() {
        let be = CpuBackend::new();
        assert_eq!(
            resolve_eval(be.manifest(), "train_step_chronicals").unwrap(),
            "eval_chronicals"
        );
        assert_eq!(resolve_eval(be.manifest(), "train_step_lora").unwrap(), "eval_lora");
        // aliases without an eval of their own fall back to the family eval
        assert_eq!(
            resolve_eval(be.manifest(), "train_step_ablate_liger").unwrap(),
            "eval_chronicals"
        );
        assert_eq!(
            resolve_eval(be.manifest(), "train_step_lora_broken").unwrap(),
            "eval_lora"
        );
        assert!(resolve_eval(be.manifest(), "train_step_nope").is_err());
    }

    #[test]
    fn rank_mismatch_is_a_build_time_error() {
        let be = CpuBackend::new();
        // the reference substrate compiles rank 4
        let err = resolve(be.manifest(), &Task::Lora { rank: Some(32) }).unwrap_err();
        assert!(err.to_string().contains("rank"), "{err}");
        assert!(resolve(be.manifest(), &Task::Lora { rank: Some(4) }).is_ok());
    }

    #[test]
    fn unknown_custom_executable_errors_with_context() {
        let be = CpuBackend::new();
        let task = Task::Custom {
            executable: "train_step_nope".into(),
            init: None,
            lora_plus_ratio: 1.0,
        };
        let err = resolve(be.manifest(), &task).unwrap_err();
        assert!(format!("{err:#}").contains("not in manifest"), "{err:#}");
    }

    #[test]
    fn lowering_recognizes_known_names() {
        assert_eq!(task_from_executable("train_step_chronicals", None, 1.0), Task::FullFinetune);
        assert_eq!(
            task_from_executable("train_step_lora", None, 1.0),
            Task::Lora { rank: None }
        );
        assert_eq!(
            task_from_executable("train_step_lora", None, 16.0),
            Task::LoraPlus { rank: None, ratio: 16.0 }
        );
        assert_eq!(task_from_executable("train_step_lora_broken", None, 1.0), Task::LoraBroken);
        // unknown names and explicit inits stay custom
        assert_eq!(
            task_from_executable("train_step_e2e", None, 1.0),
            Task::Custom { executable: "train_step_e2e".into(), init: None, lora_plus_ratio: 1.0 }
        );
        assert_eq!(
            task_from_executable("train_step_lora", Some("init_special"), 1.0),
            Task::Custom {
                executable: "train_step_lora".into(),
                init: Some("init_special".into()),
                lora_plus_ratio: 1.0
            }
        );
    }

    #[test]
    fn lowering_roundtrips_through_train_executable() {
        for name in [
            "train_step_chronicals",
            "train_step_lora",
            "train_step_ablate_naive",
            "train_step_ablate_flash",
            "train_step_ablate_compiled",
            "train_step_ablate_liger",
            "train_step_lora_naive",
            "train_step_lora_broken",
        ] {
            let task = task_from_executable(name, None, 1.0);
            assert_eq!(train_executable(&task), name);
        }
    }
}
