//! The typed Session API — the one public way to run a training workload.
//!
//! Everything that used to hand-stitch `build_corpus → make_batches →
//! LrSchedule → resolve_init → Trainer::new → run` around a stringly-typed
//! `RunConfig` goes through here instead:
//!
//! * [`SessionBuilder`] — typed knobs ([`Task`], [`Schedule`],
//!   [`PackingStrategy`], [`DataSource`], [`BackendSpec`]) with validation
//!   at build time, so a bad combination is a real error message instead of
//!   a manifest-miss panic deep inside the run.
//! * [`SessionSpec`] — the validated plain-data description of a run.
//!   `RunConfig` (TOML files, presets, legacy CLI flags) lowers into one
//!   via [`SessionSpec::from_run_config`].
//! * [`resolve`] — the single seam where tasks meet manifest executable
//!   names (`train_step_*` / `init_*` strings exist only there).
//! * [`Session`] — the built runner: it streams batches lazily
//!   ([`crate::batching::BatchStream`]: tokenize → pack → emit), stages
//!   each distinct batch on the backend once, cycles when the corpus is
//!   shorter than the run, and reports data accounting (padded tail,
//!   oversized drops) alongside the [`TrainSummary`].
//!
//! ```
//! use chronicals::session::{DataSource, PackingStrategy, SessionBuilder, Task};
//!
//! // Two full fine-tuning steps on the hermetic CPU reference backend —
//! // no artifacts, no network, sub-second.
//! let mut session = SessionBuilder::new()
//!     .task(Task::FullFinetune)
//!     .steps(2)
//!     .lr(5e-3)
//!     .data(DataSource::synthetic(64, 42, 48))
//!     .packing(PackingStrategy::Bfd)
//!     .build()?;
//! let report = session.run()?;
//! assert_eq!(report.summary.steps, 2);
//! assert!(report.summary.last_loss.is_finite());
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod resolve;

pub use crate::batching::{PackingStrategy, TailPolicy};
pub use resolve::{resolve_init, Resolved};

use crate::backend::{create_backend, Backend, DeviceBatch};
use crate::batching::BatchStream;
use crate::checkpoint::Codec;
use crate::config::RunConfig;
use crate::coordinator::{StepRecord, Trainer, TrainSummary};
use crate::data::{self, TokenizedExample};
use anyhow::{bail, Result};
use std::fmt;
use std::path::Path;
use std::rc::Rc;

/// What to train — the typed replacement for the `executable: String` zoo.
/// Variants cover the paper tables (full fine-tuning, LoRA, LoRA+, the
/// ablation ladder rungs and the intentionally-broken §8 configs); the
/// escape hatch for anything else is [`Task::Custom`].
#[derive(Debug, Clone, PartialEq)]
pub enum Task {
    /// Full fine-tuning with the complete Chronicals stack (paper Table 2).
    FullFinetune,
    /// LoRA adapters. `rank: None` accepts whatever rank the backend's
    /// executable was compiled with; `Some(r)` is validated against it.
    Lora { rank: Option<usize> },
    /// LoRA+ — dual learning rate with `lr_B = ratio · lr_A` (paper Thm. 1,
    /// λ ≈ 16).
    LoraPlus { rank: Option<usize>, ratio: f64 },
    /// Ablation ladder rung: eager baseline (paper Table 4).
    AblateNaive,
    /// Ablation ladder rung: + FlashAttention.
    AblateFlash,
    /// Ablation ladder rung: + whole-graph compile.
    AblateCompiled,
    /// Ablation ladder rung: + fused kernels & Cut Cross-Entropy.
    AblateLiger,
    /// The Unsloth-shaped naive LoRA baseline (paper Table 3).
    LoraNaive,
    /// The intentionally-broken zero-gradient "fast mode" (paper §8 /
    /// Fig. 10) — trains nothing while reporting high throughput.
    LoraBroken,
    /// Escape hatch: run a manifest executable by name (the legacy
    /// `--executable` path). `init: None` derives `init_<variant>` with the
    /// geometry-matching fallback.
    Custom { executable: String, init: Option<String>, lora_plus_ratio: f64 },
}

impl Task {
    /// Plain LoRA at the backend-default rank.
    pub fn lora() -> Task {
        Task::Lora { rank: None }
    }

    /// LoRA+ at the backend-default rank.
    pub fn lora_plus(ratio: f64) -> Task {
        Task::LoraPlus { rank: None, ratio }
    }

    /// The escape hatch for a manifest executable by name.
    pub fn custom(executable: impl Into<String>) -> Task {
        Task::Custom { executable: executable.into(), init: None, lora_plus_ratio: 1.0 }
    }

    /// Effective LoRA+ ratio λ for the lr schedule (1.0 = off).
    pub fn lora_plus_ratio(&self) -> f64 {
        match self {
            Task::LoraPlus { ratio, .. } => *ratio,
            Task::Custom { lora_plus_ratio, .. } => *lora_plus_ratio,
            _ => 1.0,
        }
    }

    /// Parse a CLI task name (`--task`), composing the optional
    /// `--lora-rank` / `--lora-plus-ratio` flags.
    pub fn parse(name: &str, rank: Option<usize>, ratio: Option<f64>) -> Result<Task> {
        let base = match name {
            "full-ft" | "full_ft" | "full" => Task::FullFinetune,
            "lora" => Task::Lora { rank },
            "lora-plus" | "lora_plus" => Task::LoraPlus { rank, ratio: ratio.unwrap_or(16.0) },
            "ablate-naive" | "ablate_naive" => Task::AblateNaive,
            "ablate-flash" | "ablate_flash" => Task::AblateFlash,
            "ablate-compiled" | "ablate_compiled" => Task::AblateCompiled,
            "ablate-liger" | "ablate_liger" => Task::AblateLiger,
            "lora-naive" | "lora_naive" => Task::LoraNaive,
            "lora-broken" | "lora_broken" => Task::LoraBroken,
            other => bail!(
                "unknown task '{other}' (expected full-ft | lora | lora-plus | ablate-naive | \
                 ablate-flash | ablate-compiled | ablate-liger | lora-naive | lora-broken)"
            ),
        };
        match base {
            Task::Lora { rank } => Ok(match ratio {
                Some(r) => Task::LoraPlus { rank, ratio: r },
                None => Task::Lora { rank },
            }),
            Task::LoraPlus { .. } => Ok(base),
            _ => {
                if ratio.is_some() {
                    bail!("--lora-plus-ratio requires a LoRA task ({base} is not one)");
                }
                if rank.is_some() {
                    bail!("--lora-rank requires a LoRA task ({base} is not one)");
                }
                Ok(base)
            }
        }
    }
}

impl fmt::Display for Task {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Task::FullFinetune => write!(f, "task full-ft"),
            Task::Lora { rank: None } => write!(f, "task lora"),
            Task::Lora { rank: Some(r) } => write!(f, "task lora (rank {r})"),
            Task::LoraPlus { rank: None, ratio } => write!(f, "task lora-plus (λ={ratio})"),
            Task::LoraPlus { rank: Some(r), ratio } => {
                write!(f, "task lora-plus (rank {r}, λ={ratio})")
            }
            Task::AblateNaive => write!(f, "task ablate-naive"),
            Task::AblateFlash => write!(f, "task ablate-flash"),
            Task::AblateCompiled => write!(f, "task ablate-compiled"),
            Task::AblateLiger => write!(f, "task ablate-liger"),
            Task::LoraNaive => write!(f, "task lora-naive"),
            Task::LoraBroken => write!(f, "task lora-broken"),
            Task::Custom { executable, .. } => write!(f, "custom task '{executable}'"),
        }
    }
}

/// Learning-rate schedule (paper Table 7: constant or warmup + cosine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    Constant,
    WarmupCosine { warmup: u64 },
}

impl Schedule {
    /// Parse a CLI schedule name (`--schedule`), composing `--lr-warmup`.
    pub fn parse(name: &str, warmup: u64) -> Result<Schedule> {
        Ok(match name {
            "constant" => Schedule::Constant,
            "warmup-cosine" | "warmup_cosine" | "cosine" => Schedule::WarmupCosine { warmup },
            other => bail!("unknown schedule '{other}' (expected constant | warmup-cosine)"),
        })
    }

    /// Concrete per-step schedule for a run of `steps` steps.
    pub fn lr_schedule(&self, lr: f64, steps: u64, lora_plus_ratio: f64) -> crate::optim::LrSchedule {
        match self {
            Schedule::Constant => crate::optim::LrSchedule::constant(lr, lora_plus_ratio),
            Schedule::WarmupCosine { warmup } => {
                crate::optim::LrSchedule::warmup_cosine(lr, *warmup, steps, lora_plus_ratio)
            }
        }
    }
}

/// Execution backend selection (typed mirror of `--backend`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendSpec {
    /// Pure-Rust deterministic reference backend (the default oracle).
    Cpu,
    /// Threaded fused-kernel CPU backend; `threads: 0` = autodetect.
    CpuFast { threads: usize },
    /// AOT artifacts via PJRT (needs a `--features pjrt` build).
    Pjrt { artifacts_dir: String },
}

impl BackendSpec {
    /// Parse a CLI/config backend name.
    pub fn parse(name: &str, artifacts_dir: &str, threads: usize) -> Result<BackendSpec> {
        Ok(match name {
            "cpu" => BackendSpec::Cpu,
            "cpu-fast" | "cpu_fast" => BackendSpec::CpuFast { threads },
            "pjrt" => BackendSpec::Pjrt { artifacts_dir: artifacts_dir.to_string() },
            other => bail!("unknown backend '{other}' (expected cpu | cpu-fast | pjrt)"),
        })
    }

    /// Instantiate the backend.
    pub fn create(&self) -> Result<Rc<dyn Backend>> {
        match self {
            BackendSpec::Cpu => create_backend("cpu", "", 0),
            BackendSpec::CpuFast { threads } => create_backend("cpu-fast", "", *threads),
            BackendSpec::Pjrt { artifacts_dir } => create_backend("pjrt", artifacts_dir, 0),
        }
    }
}

/// A pluggable source of tokenized training examples. Implement this to
/// feed real datasets through the session pipeline; the synthetic corpus
/// is the built-in implementation.
pub trait ExampleSource {
    /// Human-readable label for logs and reports.
    fn label(&self) -> String;
    /// Produce tokenized examples with every token id `< vocab_cap`.
    fn examples(&self, vocab_cap: usize) -> Result<Vec<TokenizedExample>>;
}

/// Where training data comes from.
#[derive(Clone)]
pub enum DataSource {
    /// The built-in synthetic instruction corpus (the paper's
    /// Alpaca-shaped substitute, DESIGN.md §2): `examples` examples from
    /// `seed`, each truncated to `max_seq` tokens.
    Synthetic { examples: usize, seed: u64, max_seq: usize },
    /// Any external source behind the [`ExampleSource`] trait.
    Custom(Rc<dyn ExampleSource>),
}

impl DataSource {
    pub fn synthetic(examples: usize, seed: u64, max_seq: usize) -> DataSource {
        DataSource::Synthetic { examples, seed, max_seq }
    }

    /// Materialize the tokenized example set.
    pub fn tokenized(&self, vocab_cap: usize) -> Result<Vec<TokenizedExample>> {
        match self {
            DataSource::Synthetic { examples, seed, max_seq } => {
                Ok(data::build_corpus(*examples, *seed, vocab_cap, *max_seq).1)
            }
            DataSource::Custom(src) => src.examples(vocab_cap),
        }
    }

    pub fn label(&self) -> String {
        match self {
            DataSource::Synthetic { examples, seed, max_seq } => {
                format!("synthetic({examples} examples, seed {seed}, max_seq {max_seq})")
            }
            DataSource::Custom(src) => src.label(),
        }
    }
}

impl fmt::Debug for DataSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

impl PartialEq for DataSource {
    fn eq(&self, other: &DataSource) -> bool {
        match (self, other) {
            (
                DataSource::Synthetic { examples: a, seed: b, max_seq: c },
                DataSource::Synthetic { examples: x, seed: y, max_seq: z },
            ) => a == x && b == y && c == z,
            (DataSource::Custom(a), DataSource::Custom(b)) => Rc::ptr_eq(a, b),
            _ => false,
        }
    }
}

/// The validated, typed description of one training run. Built by
/// [`SessionBuilder`] or lowered from a legacy [`RunConfig`]; turned into a
/// runnable [`Session`] by [`SessionSpec::build`].
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSpec {
    pub task: Task,
    pub schedule: Schedule,
    pub packing: PackingStrategy,
    pub data: DataSource,
    pub backend: BackendSpec,
    pub steps: u64,
    /// Throughput-meter warmup steps excluded from tokens/sec.
    pub meter_warmup: usize,
    pub seed: u64,
    pub lr: f64,
}

impl SessionSpec {
    /// Validate everything that can be checked without a backend manifest.
    /// (Manifest-dependent checks — unknown executables, LoRA rank
    /// mismatches — happen in [`resolve::resolve`] at build time.)
    pub fn validate(&self) -> Result<()> {
        if self.steps == 0 {
            bail!("steps must be positive");
        }
        if !self.lr.is_finite() || self.lr <= 0.0 {
            bail!("learning rate must be positive and finite (got {})", self.lr);
        }
        if let Schedule::WarmupCosine { warmup } = self.schedule {
            if warmup >= self.steps {
                bail!(
                    "lr warmup ({warmup} steps) must be shorter than the run ({} steps)",
                    self.steps
                );
            }
        }
        match &self.task {
            Task::LoraPlus { ratio, .. } => {
                if !ratio.is_finite() || *ratio <= 0.0 {
                    bail!("LoRA+ ratio λ must be positive and finite (got {ratio})");
                }
            }
            Task::Custom { executable, lora_plus_ratio, .. } => {
                if executable.is_empty() {
                    bail!("custom task needs a non-empty executable name");
                }
                if !lora_plus_ratio.is_finite() || *lora_plus_ratio <= 0.0 {
                    bail!("LoRA+ ratio λ must be positive and finite (got {lora_plus_ratio})");
                }
            }
            _ => {}
        }
        if let DataSource::Synthetic { examples, max_seq, .. } = &self.data {
            if *examples == 0 {
                bail!("synthetic data source needs at least one example");
            }
            if *max_seq == 0 {
                bail!("synthetic data source needs max_seq > 0");
            }
        }
        Ok(())
    }

    /// Lower a legacy [`RunConfig`] (TOML file, preset or legacy CLI flags)
    /// into a typed spec. Known executable names become typed tasks; the
    /// rest go through [`Task::Custom`], so `--executable` keeps working as
    /// an escape hatch and both paths produce identical runs.
    pub fn from_run_config(cfg: &RunConfig) -> Result<SessionSpec> {
        let init =
            if cfg.init_executable.is_empty() { None } else { Some(cfg.init_executable.as_str()) };
        let task = resolve::task_from_executable(&cfg.executable, init, cfg.lora_plus_ratio);
        let schedule = match cfg.lr_schedule.as_str() {
            "constant" => Schedule::Constant,
            "warmup_cosine" | "warmup-cosine" => {
                Schedule::WarmupCosine { warmup: cfg.lr_warmup_steps }
            }
            other => bail!("unknown lr_schedule '{other}' (expected constant | warmup_cosine)"),
        };
        let packing = if cfg.packed { PackingStrategy::Bfd } else { PackingStrategy::Padded };
        let backend =
            BackendSpec::parse(&cfg.backend, &cfg.artifacts_dir, cfg.effective_threads())?;
        let spec = SessionSpec {
            task,
            schedule,
            packing,
            data: DataSource::Synthetic {
                examples: cfg.corpus_examples,
                seed: cfg.seed,
                max_seq: cfg.max_seq,
            },
            backend,
            steps: cfg.steps,
            meter_warmup: cfg.warmup_steps,
            seed: cfg.seed,
            lr: cfg.lr,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Build a runnable session, creating the backend from
    /// [`SessionSpec::backend`].
    pub fn build(self) -> Result<Session> {
        let backend = self.backend.create()?;
        Session::with_backend(self, backend)
    }
}

/// Fluent builder for a [`SessionSpec`] / [`Session`]. Defaults mirror
/// `RunConfig::default()`: 50 steps, lr 2e-4, seed 42, BFD packing,
/// constant schedule, 2048-example synthetic corpus, CPU reference backend.
pub struct SessionBuilder {
    task: Task,
    schedule: Schedule,
    packing: PackingStrategy,
    data: Option<DataSource>,
    backend_spec: BackendSpec,
    backend: Option<Rc<dyn Backend>>,
    steps: u64,
    meter_warmup: usize,
    seed: u64,
    lr: f64,
    lora_plus_ratio: Option<f64>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder::new()
    }
}

impl SessionBuilder {
    pub fn new() -> SessionBuilder {
        SessionBuilder {
            task: Task::FullFinetune,
            schedule: Schedule::Constant,
            packing: PackingStrategy::Bfd,
            data: None,
            backend_spec: BackendSpec::Cpu,
            backend: None,
            steps: 50,
            meter_warmup: 3,
            seed: 42,
            lr: 2e-4,
            lora_plus_ratio: None,
        }
    }

    pub fn task(mut self, task: Task) -> Self {
        self.task = task;
        self
    }

    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = schedule;
        self
    }

    pub fn packing(mut self, packing: PackingStrategy) -> Self {
        self.packing = packing;
        self
    }

    pub fn data(mut self, data: DataSource) -> Self {
        self.data = Some(data);
        self
    }

    /// Select the backend by spec (created at build time).
    pub fn backend(mut self, backend: BackendSpec) -> Self {
        self.backend_spec = backend;
        self
    }

    /// Run on an already-constructed backend (tests, benches, sharing one
    /// backend across sessions). Overrides [`SessionBuilder::backend`].
    pub fn on_backend(mut self, backend: Rc<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    pub fn steps(mut self, steps: u64) -> Self {
        self.steps = steps;
        self
    }

    /// Throughput-meter warmup steps excluded from tokens/sec.
    pub fn meter_warmup(mut self, steps: usize) -> Self {
        self.meter_warmup = steps;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn lr(mut self, lr: f64) -> Self {
        self.lr = lr;
        self
    }

    /// LoRA+ ratio λ; composes with the task at build time (a [`Task::Lora`]
    /// task becomes [`Task::LoraPlus`]). Setting it on a non-LoRA task is a
    /// build error.
    pub fn lora_plus_ratio(mut self, ratio: f64) -> Self {
        self.lora_plus_ratio = Some(ratio);
        self
    }

    /// Validate and produce the plain-data spec.
    pub fn build_spec(self) -> Result<SessionSpec> {
        let task = match (self.task, self.lora_plus_ratio) {
            (t, None) => t,
            (Task::Lora { rank }, Some(r)) | (Task::LoraPlus { rank, .. }, Some(r)) => {
                Task::LoraPlus { rank, ratio: r }
            }
            (Task::Custom { executable, init, .. }, Some(r)) => {
                Task::Custom { executable, init, lora_plus_ratio: r }
            }
            (t, Some(r)) if (r - 1.0).abs() < 1e-12 => t, // λ=1 is "off"
            (t, Some(r)) => bail!("LoRA+ ratio λ={r} requires a LoRA task ({t} is not one)"),
        };
        let seed = self.seed;
        let data = self
            .data
            .unwrap_or(DataSource::Synthetic { examples: 2048, seed, max_seq: 1024 });
        let spec = SessionSpec {
            task,
            schedule: self.schedule,
            packing: self.packing,
            data,
            backend: self.backend_spec,
            steps: self.steps,
            meter_warmup: self.meter_warmup,
            seed,
            lr: self.lr,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Validate, create (or adopt) the backend, resolve the task against
    /// its manifest and initialize training state.
    pub fn build(mut self) -> Result<Session> {
        let backend = self.backend.take();
        let spec = self.build_spec()?;
        match backend {
            Some(be) => Session::with_backend(spec, be),
            None => spec.build(),
        }
    }
}

/// Everything a run reports: the training summary plus the data-pipeline
/// accounting that used to be lost silently.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub summary: TrainSummary,
    /// Examples the data source produced.
    pub examples: usize,
    /// Examples skipped by the packing plan because they exceed the row
    /// capacity `S` (paper Alg. 16 "skip oversized"). Zero for `Padded`
    /// (it truncates instead).
    pub oversized_dropped: usize,
    /// Distinct batches staged on the backend (≤ steps; the stream cycles
    /// over staged batches when the corpus is shorter than the run).
    pub batches_staged: usize,
    /// Batches the packing plan produced in total.
    pub batches_planned: usize,
    /// Whether the final planned batch carries empty padding rows (the
    /// partial tail is padded, not dropped — no example is lost).
    pub tail_padded: bool,
}

/// A built, runnable training session: backend + resolved executables +
/// trainer, driving the lazy batch stream.
pub struct Session {
    spec: SessionSpec,
    backend: Rc<dyn Backend>,
    resolved: Resolved,
    trainer: Trainer,
}

impl Session {
    /// Build on an explicit backend instance (ignores `spec.backend`).
    pub fn with_backend(spec: SessionSpec, backend: Rc<dyn Backend>) -> Result<Session> {
        spec.validate()?;
        let resolved = resolve::resolve(backend.manifest(), &spec.task)?;
        let schedule = spec.schedule.lr_schedule(spec.lr, spec.steps, resolved.lora_plus_ratio);
        let state = backend.init_state(&resolved.init, spec.seed as i32)?;
        let trainer =
            Trainer::new(backend.clone(), &resolved.train, state, schedule, spec.meter_warmup)?;
        Ok(Session { spec, backend, resolved, trainer })
    }

    pub fn spec(&self) -> &SessionSpec {
        &self.spec
    }

    /// The manifest wiring this session resolved to.
    pub fn resolved(&self) -> &Resolved {
        &self.resolved
    }

    pub fn backend(&self) -> &Rc<dyn Backend> {
        &self.backend
    }

    /// Per-step records (loss curve, grad norms) accumulated so far.
    pub fn records(&self) -> &[StepRecord] {
        &self.trainer.records
    }

    /// Direct access to the underlying trainer (eval, manual stepping).
    pub fn trainer_mut(&mut self) -> &mut Trainer {
        &mut self.trainer
    }

    /// Save current parameters to a checkpoint file.
    pub fn save_checkpoint(&self, path: impl AsRef<Path>, codec: Codec) -> Result<()> {
        self.trainer.save_checkpoint(path, codec)
    }

    /// Run the configured number of steps: tokenize → pack → stream
    /// batches lazily, staging each distinct batch on the backend once and
    /// cycling over staged batches when the stream is exhausted. The tail
    /// batch is padded, never dropped ([`TailPolicy::Pad`]).
    pub fn run(&mut self) -> Result<RunReport> {
        let exe = &self.resolved.spec;
        // vocab cap = the model's vocab so token ids stay in range
        let vocab = exe.model_config.vocab.max(64);
        let (batch, seq) = (exe.batch, exe.seq);
        let examples = self.spec.data.tokenized(vocab)?;
        let n_examples = examples.len();
        let mut stream =
            BatchStream::new(examples, self.spec.packing, batch, seq, TailPolicy::Pad);
        if stream.n_batches() == 0 {
            bail!(
                "no batches for '{}' (B={batch}, S={seq}, {n_examples} examples from {})",
                self.resolved.train,
                self.spec.data.label()
            );
        }
        let batches_planned = stream.n_batches();
        let oversized_dropped = stream.oversized_dropped();
        let tail_padded = stream.tail_padded();

        let mut staged: Vec<DeviceBatch> = Vec::new();
        for i in 0..self.spec.steps {
            match stream.next() {
                Some(b) => {
                    staged.push(self.trainer.upload_batch(&b)?);
                    let ub = staged.last().expect("just pushed");
                    self.trainer.step_uploaded(ub)?;
                }
                None => {
                    let idx = (i % staged.len() as u64) as usize;
                    self.trainer.step_uploaded(&staged[idx])?;
                }
            }
        }
        Ok(RunReport {
            summary: self.trainer.summary(),
            examples: n_examples,
            oversized_dropped,
            batches_staged: staged.len(),
            batches_planned,
            tail_padded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_validate() {
        let spec = SessionBuilder::new().build_spec().unwrap();
        assert_eq!(spec.task, Task::FullFinetune);
        assert_eq!(spec.packing, PackingStrategy::Bfd);
        assert_eq!(spec.steps, 50);
    }

    #[test]
    fn zero_steps_rejected() {
        let err = SessionBuilder::new().steps(0).build_spec().unwrap_err();
        assert!(err.to_string().contains("steps"), "{err}");
    }

    #[test]
    fn warmup_longer_than_run_rejected() {
        let err = SessionBuilder::new()
            .steps(10)
            .schedule(Schedule::WarmupCosine { warmup: 10 })
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("warmup"), "{err}");
    }

    #[test]
    fn ratio_on_non_lora_task_rejected() {
        let err = SessionBuilder::new()
            .task(Task::FullFinetune)
            .lora_plus_ratio(16.0)
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("LoRA"), "{err}");
        // λ=1 means "off" and is accepted everywhere
        assert!(SessionBuilder::new()
            .task(Task::FullFinetune)
            .lora_plus_ratio(1.0)
            .build_spec()
            .is_ok());
    }

    #[test]
    fn ratio_composes_with_lora_task() {
        let spec = SessionBuilder::new()
            .task(Task::lora())
            .lora_plus_ratio(16.0)
            .build_spec()
            .unwrap();
        assert_eq!(spec.task, Task::LoraPlus { rank: None, ratio: 16.0 });
    }

    #[test]
    fn nonpositive_ratio_rejected() {
        let err = SessionBuilder::new().task(Task::lora_plus(0.0)).build_spec().unwrap_err();
        assert!(err.to_string().contains("positive"), "{err}");
    }

    #[test]
    fn empty_corpus_rejected() {
        let err = SessionBuilder::new()
            .data(DataSource::synthetic(0, 1, 64))
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("example"), "{err}");
    }

    #[test]
    fn unknown_backend_name_rejected() {
        assert!(BackendSpec::parse("tpu", "", 0).is_err());
    }

    #[test]
    fn task_parse_cli_names() {
        assert_eq!(Task::parse("full-ft", None, None).unwrap(), Task::FullFinetune);
        assert_eq!(
            Task::parse("lora-plus", None, None).unwrap(),
            Task::LoraPlus { rank: None, ratio: 16.0 }
        );
        assert_eq!(
            Task::parse("lora", Some(4), Some(8.0)).unwrap(),
            Task::LoraPlus { rank: Some(4), ratio: 8.0 }
        );
        assert!(Task::parse("full-ft", None, Some(16.0)).is_err());
        assert!(Task::parse("ablate-naive", Some(4), None).is_err());
        assert!(Task::parse("frobnicate", None, None).is_err());
    }

    #[test]
    fn schedule_parse_names() {
        assert_eq!(Schedule::parse("constant", 0).unwrap(), Schedule::Constant);
        assert_eq!(
            Schedule::parse("warmup-cosine", 5).unwrap(),
            Schedule::WarmupCosine { warmup: 5 }
        );
        assert!(Schedule::parse("linear", 0).is_err());
    }
}
