//! The typed Session API — the one public way to run a training workload.
//!
//! Everything that used to hand-stitch `build_corpus → make_batches →
//! LrSchedule → resolve_init → Trainer::new → run` around a stringly-typed
//! `RunConfig` goes through here instead:
//!
//! * [`SessionBuilder`] — typed knobs ([`Task`], [`Schedule`],
//!   [`PackingStrategy`], [`DataSource`], [`BackendSpec`]) with validation
//!   at build time, so a bad combination is a real error message instead of
//!   a manifest-miss panic deep inside the run.
//! * [`SessionSpec`] — the validated plain-data description of a run.
//!   `RunConfig` (TOML files, presets, legacy CLI flags) lowers into one
//!   via [`SessionSpec::from_run_config`].
//! * [`resolve`] — the single seam where tasks meet manifest executable
//!   names (`train_step_*` / `init_*` strings exist only there).
//! * [`Session`] — the built runner: it streams batches lazily
//!   ([`crate::batching::BatchStream`]: tokenize → pack → emit), stages
//!   each distinct batch on the backend once, cycles when the corpus is
//!   shorter than the run, and reports data accounting (padded tail,
//!   oversized drops) alongside the [`TrainSummary`].
//!
//! ```
//! use chronicals::session::{DataSource, PackingStrategy, SessionBuilder, Task};
//!
//! // Two full fine-tuning steps on the hermetic CPU reference backend —
//! // no artifacts, no network, sub-second.
//! let mut session = SessionBuilder::new()
//!     .task(Task::FullFinetune)
//!     .steps(2)
//!     .lr(5e-3)
//!     .data(DataSource::synthetic(64, 42, 48))
//!     .packing(PackingStrategy::Bfd)
//!     .build()?;
//! let report = session.run()?;
//! assert_eq!(report.summary.steps, 2);
//! assert!(report.summary.last_loss.is_finite());
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod resolve;

pub use crate::batching::{PackingStrategy, TailPolicy};
pub use crate::data_source::LossMode;
pub use resolve::{resolve_eval, resolve_init, Resolved};

use crate::backend::{create_backend, Backend, DataParallel, DeviceBatch, MemoryCfg};
use crate::batching::{Batch, BatchStream, EpochSpec};
use crate::checkpoint::Codec;
use crate::config::RunConfig;
use crate::coordinator::{StepRecord, Trainer, TrainSummary};
use crate::data::{self, TokenizedExample};
use crate::data_source::{ChatSource, JsonlSource, SourceStats};
use crate::quant::{BaseQuant, OptimStates};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::fmt;
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

/// What to train — the typed replacement for the `executable: String` zoo.
/// Variants cover the paper tables (full fine-tuning, LoRA, LoRA+, the
/// ablation ladder rungs and the intentionally-broken §8 configs); the
/// escape hatch for anything else is [`Task::Custom`].
#[derive(Debug, Clone, PartialEq)]
pub enum Task {
    /// Full fine-tuning with the complete Chronicals stack (paper Table 2).
    FullFinetune,
    /// LoRA adapters. `rank: None` accepts whatever rank the backend's
    /// executable was compiled with; `Some(r)` is validated against it.
    Lora { rank: Option<usize> },
    /// LoRA+ — dual learning rate with `lr_B = ratio · lr_A` (paper Thm. 1,
    /// λ ≈ 16).
    LoraPlus { rank: Option<usize>, ratio: f64 },
    /// Ablation ladder rung: eager baseline (paper Table 4).
    AblateNaive,
    /// Ablation ladder rung: + FlashAttention.
    AblateFlash,
    /// Ablation ladder rung: + whole-graph compile.
    AblateCompiled,
    /// Ablation ladder rung: + fused kernels & Cut Cross-Entropy.
    AblateLiger,
    /// The Unsloth-shaped naive LoRA baseline (paper Table 3).
    LoraNaive,
    /// The intentionally-broken zero-gradient "fast mode" (paper §8 /
    /// Fig. 10) — trains nothing while reporting high throughput.
    LoraBroken,
    /// Escape hatch: run a manifest executable by name (the legacy
    /// `--executable` path). `init: None` derives `init_<variant>` with the
    /// geometry-matching fallback.
    Custom { executable: String, init: Option<String>, lora_plus_ratio: f64 },
}

impl Task {
    /// Plain LoRA at the backend-default rank.
    pub fn lora() -> Task {
        Task::Lora { rank: None }
    }

    /// LoRA+ at the backend-default rank.
    pub fn lora_plus(ratio: f64) -> Task {
        Task::LoraPlus { rank: None, ratio }
    }

    /// The escape hatch for a manifest executable by name.
    pub fn custom(executable: impl Into<String>) -> Task {
        Task::Custom { executable: executable.into(), init: None, lora_plus_ratio: 1.0 }
    }

    /// Effective LoRA+ ratio λ for the lr schedule (1.0 = off).
    pub fn lora_plus_ratio(&self) -> f64 {
        match self {
            Task::LoraPlus { ratio, .. } => *ratio,
            Task::Custom { lora_plus_ratio, .. } => *lora_plus_ratio,
            _ => 1.0,
        }
    }

    /// Parse a CLI task name (`--task`), composing the optional
    /// `--lora-rank` / `--lora-plus-ratio` flags.
    pub fn parse(name: &str, rank: Option<usize>, ratio: Option<f64>) -> Result<Task> {
        let base = match name {
            "full-ft" | "full_ft" | "full" => Task::FullFinetune,
            "lora" => Task::Lora { rank },
            "lora-plus" | "lora_plus" => Task::LoraPlus { rank, ratio: ratio.unwrap_or(16.0) },
            "ablate-naive" | "ablate_naive" => Task::AblateNaive,
            "ablate-flash" | "ablate_flash" => Task::AblateFlash,
            "ablate-compiled" | "ablate_compiled" => Task::AblateCompiled,
            "ablate-liger" | "ablate_liger" => Task::AblateLiger,
            "lora-naive" | "lora_naive" => Task::LoraNaive,
            "lora-broken" | "lora_broken" => Task::LoraBroken,
            other => bail!(
                "unknown task '{other}' (expected full-ft | lora | lora-plus | ablate-naive | \
                 ablate-flash | ablate-compiled | ablate-liger | lora-naive | lora-broken)"
            ),
        };
        match base {
            Task::Lora { rank } => Ok(match ratio {
                Some(r) => Task::LoraPlus { rank, ratio: r },
                None => Task::Lora { rank },
            }),
            Task::LoraPlus { .. } => Ok(base),
            _ => {
                if ratio.is_some() {
                    bail!("--lora-plus-ratio requires a LoRA task ({base} is not one)");
                }
                if rank.is_some() {
                    bail!("--lora-rank requires a LoRA task ({base} is not one)");
                }
                Ok(base)
            }
        }
    }
}

impl fmt::Display for Task {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Task::FullFinetune => write!(f, "task full-ft"),
            Task::Lora { rank: None } => write!(f, "task lora"),
            Task::Lora { rank: Some(r) } => write!(f, "task lora (rank {r})"),
            Task::LoraPlus { rank: None, ratio } => write!(f, "task lora-plus (λ={ratio})"),
            Task::LoraPlus { rank: Some(r), ratio } => {
                write!(f, "task lora-plus (rank {r}, λ={ratio})")
            }
            Task::AblateNaive => write!(f, "task ablate-naive"),
            Task::AblateFlash => write!(f, "task ablate-flash"),
            Task::AblateCompiled => write!(f, "task ablate-compiled"),
            Task::AblateLiger => write!(f, "task ablate-liger"),
            Task::LoraNaive => write!(f, "task lora-naive"),
            Task::LoraBroken => write!(f, "task lora-broken"),
            Task::Custom { executable, .. } => write!(f, "custom task '{executable}'"),
        }
    }
}

/// Learning-rate schedule (paper Table 7: constant or warmup + cosine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    Constant,
    WarmupCosine { warmup: u64 },
}

impl Schedule {
    /// Parse a CLI schedule name (`--schedule`), composing `--lr-warmup`.
    pub fn parse(name: &str, warmup: u64) -> Result<Schedule> {
        Ok(match name {
            "constant" => Schedule::Constant,
            "warmup-cosine" | "warmup_cosine" | "cosine" => Schedule::WarmupCosine { warmup },
            other => bail!("unknown schedule '{other}' (expected constant | warmup-cosine)"),
        })
    }

    /// Concrete per-step schedule for a run of `steps` steps.
    pub fn lr_schedule(&self, lr: f64, steps: u64, lora_plus_ratio: f64) -> crate::optim::LrSchedule {
        match self {
            Schedule::Constant => crate::optim::LrSchedule::constant(lr, lora_plus_ratio),
            Schedule::WarmupCosine { warmup } => {
                crate::optim::LrSchedule::warmup_cosine(lr, *warmup, steps, lora_plus_ratio)
            }
        }
    }
}

/// Execution backend selection (typed mirror of `--backend`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendSpec {
    /// Pure-Rust deterministic reference backend (the default oracle).
    Cpu,
    /// Threaded fused-kernel CPU backend; `threads: 0` = autodetect.
    CpuFast { threads: usize },
    /// AOT artifacts via PJRT (needs a `--features pjrt` build).
    Pjrt { artifacts_dir: String },
}

impl BackendSpec {
    /// Parse a CLI/config backend name.
    pub fn parse(name: &str, artifacts_dir: &str, threads: usize) -> Result<BackendSpec> {
        Ok(match name {
            "cpu" => BackendSpec::Cpu,
            "cpu-fast" | "cpu_fast" => BackendSpec::CpuFast { threads },
            "pjrt" => BackendSpec::Pjrt { artifacts_dir: artifacts_dir.to_string() },
            other => bail!("unknown backend '{other}' (expected cpu | cpu-fast | pjrt)"),
        })
    }

    /// Instantiate the backend.
    pub fn create(&self) -> Result<Arc<dyn Backend>> {
        match self {
            BackendSpec::Cpu => create_backend("cpu", "", 0),
            BackendSpec::CpuFast { threads } => create_backend("cpu-fast", "", *threads),
            BackendSpec::Pjrt { artifacts_dir } => create_backend("pjrt", artifacts_dir, 0),
        }
    }
}

/// A pluggable source of tokenized training examples. Implement this to
/// feed real datasets through the session pipeline; the synthetic corpus
/// and the file-backed [`JsonlSource`] are the built-in implementations.
pub trait ExampleSource {
    /// Human-readable label for logs and reports.
    fn label(&self) -> String;
    /// Produce tokenized examples with every token id `< vocab_cap`.
    fn examples(&self, vocab_cap: usize) -> Result<Vec<TokenizedExample>>;
    /// Accounting from the last [`ExampleSource::examples`] call
    /// (malformed / truncated records). Defaults to all-zeros for sources
    /// that cannot fail per record.
    fn stats(&self) -> SourceStats {
        SourceStats::default()
    }
}

/// Where training data comes from.
#[derive(Clone)]
pub enum DataSource {
    /// The built-in synthetic instruction corpus (the paper's
    /// Alpaca-shaped substitute, DESIGN.md §2): `examples` examples from
    /// `seed`, each truncated to `max_seq` tokens.
    Synthetic {
        /// Number of generated examples.
        examples: usize,
        /// Corpus-generation seed.
        seed: u64,
        /// Token cap per example (longer examples are truncated).
        max_seq: usize,
    },
    /// A file-backed instruction-tuning JSONL corpus
    /// (`{"prompt", "completion"}` records with a `{"text"}` fallback),
    /// streamed and tokenized by the byte-level mini-BPE
    /// ([`crate::data_source`], DESIGN.md §8).
    Jsonl {
        /// Path to the `.jsonl` corpus file.
        file: String,
        /// Optional tokenizer vocab file: loaded when present, learned
        /// from the corpus and written there when absent.
        vocab_file: Option<String>,
        /// Tokenizer-learning seed (merge tie-breaks).
        seed: u64,
        /// Token cap per example (longer records are truncated + counted).
        max_seq: usize,
    },
    /// A chat-transcript JSONL corpus — every record must be a
    /// `{"messages": [{"role", "content"}, …]}` transcript
    /// ([`crate::data_source::ChatSource`]): role-framed turns with
    /// per-turn loss masks under the session's [`LossMode`].
    Chat {
        /// Path to the `.jsonl` / `.jsonl.gz` transcript file.
        file: String,
        /// Optional tokenizer vocab file: loaded when present, learned
        /// from the corpus and written there when absent.
        vocab_file: Option<String>,
        /// Tokenizer-learning seed (merge tie-breaks).
        seed: u64,
        /// Token cap per example (longer records are truncated + counted).
        max_seq: usize,
    },
    /// Any external source behind the [`ExampleSource`] trait.
    Custom(Rc<dyn ExampleSource>),
}

impl DataSource {
    pub fn synthetic(examples: usize, seed: u64, max_seq: usize) -> DataSource {
        DataSource::Synthetic { examples, seed, max_seq }
    }

    /// A file-backed JSONL corpus with an in-memory (re-learned per run,
    /// still deterministic) tokenizer. Set the `vocab_file` field on
    /// [`DataSource::Jsonl`] to persist the vocabulary.
    ///
    /// ```
    /// use chronicals::session::{DataSource, SessionBuilder};
    ///
    /// let path = std::env::temp_dir().join("chronicals_ds_doc.jsonl");
    /// std::fs::write(&path, "{\"text\": \"tokens stream into packed bins\"}\n")?;
    /// let mut session = SessionBuilder::new()
    ///     .steps(1)
    ///     .lr(5e-3)
    ///     .data(DataSource::jsonl(path.to_str().unwrap(), 7, 64))
    ///     .build()?;
    /// let report = session.run()?;
    /// assert_eq!(report.examples, 1);
    /// # std::fs::remove_file(&path).ok();
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn jsonl(file: impl Into<String>, seed: u64, max_seq: usize) -> DataSource {
        DataSource::Jsonl { file: file.into(), vocab_file: None, seed, max_seq }
    }

    /// A chat-transcript JSONL corpus (`{"messages": [...]}` records only;
    /// `.jsonl.gz` streams through the hermetic inflater).
    ///
    /// ```
    /// use chronicals::session::{DataSource, SessionBuilder};
    ///
    /// let path = std::env::temp_dir().join("chronicals_ds_chat_doc.jsonl");
    /// std::fs::write(
    ///     &path,
    ///     "{\"messages\": [{\"role\": \"user\", \"content\": \"pack bins\"}, \
    ///       {\"role\": \"assistant\", \"content\": \"bfd packs tightly\"}]}\n",
    /// )?;
    /// let mut session = SessionBuilder::new()
    ///     .steps(1)
    ///     .lr(5e-3)
    ///     .data(DataSource::chat(path.to_str().unwrap(), 7, 64))
    ///     .build()?;
    /// let report = session.run()?;
    /// assert_eq!(report.examples, 1);
    /// # std::fs::remove_file(&path).ok();
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn chat(file: impl Into<String>, seed: u64, max_seq: usize) -> DataSource {
        DataSource::Chat { file: file.into(), vocab_file: None, seed, max_seq }
    }

    /// Materialize the tokenized example set plus the source's
    /// malformed/truncated accounting. `loss_mode` selects which positions
    /// are supervised (file-backed sources only; the synthetic corpus has
    /// its masking baked in).
    pub fn tokenized(
        &self,
        vocab_cap: usize,
        loss_mode: LossMode,
    ) -> Result<(Vec<TokenizedExample>, SourceStats)> {
        match self {
            DataSource::Synthetic { examples, seed, max_seq } => Ok((
                data::build_corpus(*examples, *seed, vocab_cap, *max_seq).1,
                SourceStats::default(),
            )),
            DataSource::Jsonl { file, vocab_file, seed, max_seq } => {
                let mut src = JsonlSource::new(file, *seed, *max_seq).with_loss_mode(loss_mode);
                if let Some(vf) = vocab_file {
                    src = src.with_vocab_file(vf);
                }
                let exs = src.examples(vocab_cap)?;
                let stats = src.stats();
                Ok((exs, stats))
            }
            DataSource::Chat { file, vocab_file, seed, max_seq } => {
                let mut src = ChatSource::new(file, *seed, *max_seq).with_loss_mode(loss_mode);
                if let Some(vf) = vocab_file {
                    src = src.with_vocab_file(vf);
                }
                let exs = src.examples(vocab_cap)?;
                let stats = src.stats();
                Ok((exs, stats))
            }
            DataSource::Custom(src) => Ok((src.examples(vocab_cap)?, src.stats())),
        }
    }

    pub fn label(&self) -> String {
        match self {
            DataSource::Synthetic { examples, seed, max_seq } => {
                format!("synthetic({examples} examples, seed {seed}, max_seq {max_seq})")
            }
            DataSource::Jsonl { file, .. } => format!("jsonl({file})"),
            DataSource::Chat { file, .. } => format!("chat({file})"),
            DataSource::Custom(src) => src.label(),
        }
    }
}

impl fmt::Debug for DataSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

impl PartialEq for DataSource {
    fn eq(&self, other: &DataSource) -> bool {
        match (self, other) {
            (
                DataSource::Synthetic { examples: a, seed: b, max_seq: c },
                DataSource::Synthetic { examples: x, seed: y, max_seq: z },
            ) => a == x && b == y && c == z,
            (
                DataSource::Jsonl { file: a, vocab_file: b, seed: c, max_seq: d },
                DataSource::Jsonl { file: w, vocab_file: x, seed: y, max_seq: z },
            ) => a == w && b == x && c == y && d == z,
            (
                DataSource::Chat { file: a, vocab_file: b, seed: c, max_seq: d },
                DataSource::Chat { file: w, vocab_file: x, seed: y, max_seq: z },
            ) => a == w && b == x && c == y && d == z,
            (DataSource::Custom(a), DataSource::Custom(b)) => Rc::ptr_eq(a, b),
            _ => false,
        }
    }
}

/// How the run walks the data: how many passes it makes over the packing
/// plan and whether each pass reorders it. The default (`shuffle: None`,
/// `epochs: None`) is bit-for-bit the legacy behavior: plan order, run
/// exactly `steps` steps, cycling staged batches once the plan is
/// exhausted.
///
/// Shuffling permutes the *plan* (the order packed bins enter batches) —
/// examples are tokenized and packed exactly once, never re-tokenized, and
/// every epoch carries the same token multiset (DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EpochPolicy {
    /// Deterministic per-epoch plan shuffle seed; `None` keeps plan order.
    pub shuffle: Option<u64>,
    /// `Some(n)`: run exactly `n` passes over the data — the run length
    /// becomes `n × batches-per-epoch` and the lr schedule spans it
    /// (`steps` is ignored). `None`: cycle to `steps`.
    pub epochs: Option<u64>,
}

/// The validated, typed description of one training run. Built by
/// [`SessionBuilder`] or lowered from a legacy [`RunConfig`]; turned into a
/// runnable [`Session`] by [`SessionSpec::build`].
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSpec {
    pub task: Task,
    pub schedule: Schedule,
    pub packing: PackingStrategy,
    pub data: DataSource,
    /// Shuffle/epoch policy for the batch plan (default: legacy cycling).
    pub epoch_policy: EpochPolicy,
    /// Which token positions the loss supervises (file-backed sources;
    /// default [`LossMode::ResponseOnly`]).
    pub loss_mode: LossMode,
    /// `Some(f)`: hold out ⌊f · examples⌋ examples (seeded by
    /// [`SessionSpec::seed`], disjoint from the train set, stable under
    /// shuffle/epoch settings) and report periodic forward-only eval loss.
    /// `None` (default): no eval split.
    pub eval_fraction: Option<f64>,
    pub backend: BackendSpec,
    /// Data-parallel replica count. `0` (default) = the legacy
    /// single-backend path, bit-identical to every release before workers
    /// existed. `n ≥ 1` = build `n` backend replicas from
    /// [`SessionSpec::backend`] and wrap them in
    /// [`crate::backend::DataParallel`]: each batch is sharded row-wise,
    /// per-row gradients combine through a fixed-order reduction tree, and
    /// the optimizer steps once on the reduced gradient — so the loss /
    /// grad-norm / eval series are bitwise invariant across worker counts
    /// (DESIGN.md §10). Even `n = 1` goes through the sharded path.
    pub workers: usize,
    pub steps: u64,
    /// Throughput-meter warmup steps excluded from tokens/sec.
    pub meter_warmup: usize,
    pub seed: u64,
    pub lr: f64,
    /// Memory tier 1: AdamW m/v slot codec (`--optim-states fp32|int8`,
    /// TOML `optim.states`; default fp32 — the legacy bitwise path).
    pub optim_states: OptimStates,
    /// Memory tier 2: frozen-base weight codec for LoRA-family tasks
    /// (`--base-quant none|int8|fp8`, TOML `optim.base_quant`; default
    /// `None` = dense f32). Rejected for tasks that train the base.
    pub base_quant: Option<BaseQuant>,
    /// Memory tier 3: activation-checkpoint segment count
    /// (`--ckpt-segments N`, TOML `optim.ckpt_segments`; 0 = off — keep
    /// every layer activation cached for backward).
    pub ckpt_segments: usize,
}

impl SessionSpec {
    /// Validate everything that can be checked without a backend manifest.
    /// (Manifest-dependent checks — unknown executables, LoRA rank
    /// mismatches — happen in [`resolve::resolve`] at build time.)
    pub fn validate(&self) -> Result<()> {
        if self.steps == 0 {
            bail!("steps must be positive");
        }
        if !self.lr.is_finite() || self.lr <= 0.0 {
            bail!("learning rate must be positive and finite (got {})", self.lr);
        }
        if let Schedule::WarmupCosine { warmup } = self.schedule {
            if warmup >= self.steps {
                bail!(
                    "lr warmup ({warmup} steps) must be shorter than the run ({} steps)",
                    self.steps
                );
            }
        }
        match &self.task {
            Task::LoraPlus { ratio, .. } => {
                if !ratio.is_finite() || *ratio <= 0.0 {
                    bail!("LoRA+ ratio λ must be positive and finite (got {ratio})");
                }
            }
            Task::Custom { executable, lora_plus_ratio, .. } => {
                if executable.is_empty() {
                    bail!("custom task needs a non-empty executable name");
                }
                if !lora_plus_ratio.is_finite() || *lora_plus_ratio <= 0.0 {
                    bail!("LoRA+ ratio λ must be positive and finite (got {lora_plus_ratio})");
                }
            }
            _ => {}
        }
        match &self.data {
            DataSource::Synthetic { examples, max_seq, .. } => {
                if *examples == 0 {
                    bail!("synthetic data source needs at least one example");
                }
                if *max_seq == 0 {
                    bail!("synthetic data source needs max_seq > 0");
                }
            }
            DataSource::Jsonl { file, max_seq, .. } => {
                if file.is_empty() {
                    bail!("jsonl data source needs a file path");
                }
                if *max_seq == 0 {
                    bail!("jsonl data source needs max_seq > 0");
                }
            }
            DataSource::Chat { file, max_seq, .. } => {
                if file.is_empty() {
                    bail!("chat data source needs a file path");
                }
                if *max_seq == 0 {
                    bail!("chat data source needs max_seq > 0");
                }
            }
            DataSource::Custom(_) => {}
        }
        if self.epoch_policy.epochs == Some(0) {
            bail!("epochs must be ≥ 1 (use epochs: None for step-count cycling)");
        }
        if self.workers > 0 {
            if let BackendSpec::Pjrt { .. } = self.backend {
                bail!(
                    "data-parallel workers need a backend that supports per-row \
                     gradient sharding (cpu | cpu-fast); the pjrt artifact runtime \
                     does not"
                );
            }
            if self.workers > 64 {
                bail!("workers must be ≤ 64 (got {})", self.workers);
            }
        }
        if let Some(f) = self.eval_fraction {
            if !f.is_finite() || f <= 0.0 {
                bail!(
                    "eval fraction must be positive and finite (got {f}); \
                     omit --eval-fraction to train on everything"
                );
            }
            if f >= 1.0 {
                bail!(
                    "eval fraction must be < 1 so at least one example trains (got {f})"
                );
            }
        }
        if self.base_quant.is_some() {
            match &self.task {
                // LoRA-family: the base is frozen, so it may be quantized.
                Task::Lora { .. }
                | Task::LoraPlus { .. }
                | Task::LoraNaive
                | Task::LoraBroken => {}
                // Custom executables resolve at build time; the backend's
                // own frozen-base check rejects non-LoRA states there.
                Task::Custom { .. } => {}
                other => bail!(
                    "--base-quant requires a LoRA-family task whose base weights \
                     are frozen ({other} trains the base, so quantizing it would \
                     corrupt the optimizer trajectory)"
                ),
            }
        }
        Ok(())
    }

    /// The memory-tier configuration this spec requests, pushed onto the
    /// freshly initialized state via [`crate::backend::Backend::configure_memory`].
    pub fn memory_cfg(&self) -> MemoryCfg {
        MemoryCfg {
            optim_states: self.optim_states,
            base_quant: self.base_quant,
            ckpt_segments: self.ckpt_segments,
        }
    }

    /// Lower a legacy [`RunConfig`] (TOML file, preset or legacy CLI flags)
    /// into a typed spec. Known executable names become typed tasks; the
    /// rest go through [`Task::Custom`], so `--executable` keeps working as
    /// an escape hatch and both paths produce identical runs.
    pub fn from_run_config(cfg: &RunConfig) -> Result<SessionSpec> {
        let init =
            if cfg.init_executable.is_empty() { None } else { Some(cfg.init_executable.as_str()) };
        let task = resolve::task_from_executable(&cfg.executable, init, cfg.lora_plus_ratio);
        let schedule = match cfg.lr_schedule.as_str() {
            "constant" => Schedule::Constant,
            "warmup_cosine" | "warmup-cosine" => {
                Schedule::WarmupCosine { warmup: cfg.lr_warmup_steps }
            }
            other => bail!("unknown lr_schedule '{other}' (expected constant | warmup_cosine)"),
        };
        let packing = if cfg.packed { PackingStrategy::Bfd } else { PackingStrategy::Padded };
        let backend =
            BackendSpec::parse(&cfg.backend, &cfg.artifacts_dir, cfg.effective_threads())?;
        let data = if cfg.data_file.is_empty() {
            DataSource::Synthetic {
                examples: cfg.corpus_examples,
                seed: cfg.seed,
                max_seq: cfg.max_seq,
            }
        } else {
            DataSource::Jsonl {
                file: cfg.data_file.clone(),
                vocab_file: (!cfg.tokenizer_file.is_empty()).then(|| cfg.tokenizer_file.clone()),
                seed: cfg.seed,
                max_seq: cfg.max_seq,
            }
        };
        let loss_mode = if cfg.loss_mode.is_empty() {
            LossMode::default()
        } else {
            crate::data_source::LossMode::parse(&cfg.loss_mode)?
        };
        let optim_states = if cfg.optim_states.is_empty() {
            OptimStates::default()
        } else {
            OptimStates::parse(&cfg.optim_states)?
        };
        let base_quant = match cfg.base_quant.as_str() {
            "" | "none" => None,
            name => Some(BaseQuant::parse(name)?),
        };
        let spec = SessionSpec {
            task,
            schedule,
            packing,
            data,
            epoch_policy: EpochPolicy { shuffle: cfg.shuffle_seed, epochs: cfg.epochs },
            loss_mode,
            eval_fraction: cfg.eval_fraction,
            backend,
            workers: cfg.workers,
            steps: cfg.steps,
            meter_warmup: cfg.warmup_steps,
            seed: cfg.seed,
            lr: cfg.lr,
            optim_states,
            base_quant,
            ckpt_segments: cfg.ckpt_segments,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Build a runnable session, creating the backend from
    /// [`SessionSpec::backend`] (wrapped in [`DataParallel`] over
    /// [`SessionSpec::workers`] replicas when workers are requested).
    pub fn build(self) -> Result<Session> {
        let backend = self.create_backend()?;
        Session::with_backend(self, backend)
    }

    /// Instantiate the execution backend this spec describes: the plain
    /// backend when `workers == 0`, otherwise `workers` independent
    /// replicas behind the [`DataParallel`] reduction tree.
    pub fn create_backend(&self) -> Result<Arc<dyn Backend>> {
        if self.workers == 0 {
            return self.backend.create();
        }
        let replicas = (0..self.workers)
            .map(|_| self.backend.create())
            .collect::<Result<Vec<_>>>()?;
        Ok(Arc::new(DataParallel::from_replicas(replicas)?))
    }
}

/// Fluent builder for a [`SessionSpec`] / [`Session`]. Defaults mirror
/// `RunConfig::default()`: 50 steps, lr 2e-4, seed 42, BFD packing,
/// constant schedule, 2048-example synthetic corpus, CPU reference backend.
pub struct SessionBuilder {
    task: Task,
    schedule: Schedule,
    packing: PackingStrategy,
    data: Option<DataSource>,
    epoch_policy: EpochPolicy,
    loss_mode: LossMode,
    eval_fraction: Option<f64>,
    backend_spec: BackendSpec,
    backend: Option<Arc<dyn Backend>>,
    workers: usize,
    steps: u64,
    meter_warmup: usize,
    seed: u64,
    lr: f64,
    lora_plus_ratio: Option<f64>,
    optim_states: OptimStates,
    base_quant: Option<BaseQuant>,
    ckpt_segments: usize,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder::new()
    }
}

impl SessionBuilder {
    pub fn new() -> SessionBuilder {
        SessionBuilder {
            task: Task::FullFinetune,
            schedule: Schedule::Constant,
            packing: PackingStrategy::Bfd,
            data: None,
            epoch_policy: EpochPolicy::default(),
            loss_mode: LossMode::default(),
            eval_fraction: None,
            backend_spec: BackendSpec::Cpu,
            backend: None,
            workers: 0,
            steps: 50,
            meter_warmup: 3,
            seed: 42,
            lr: 2e-4,
            lora_plus_ratio: None,
            optim_states: OptimStates::default(),
            base_quant: None,
            ckpt_segments: 0,
        }
    }

    pub fn task(mut self, task: Task) -> Self {
        self.task = task;
        self
    }

    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = schedule;
        self
    }

    pub fn packing(mut self, packing: PackingStrategy) -> Self {
        self.packing = packing;
        self
    }

    pub fn data(mut self, data: DataSource) -> Self {
        self.data = Some(data);
        self
    }

    /// Shuffle the packing plan deterministically each epoch (a *plan*
    /// permutation: nothing is re-tokenized, every epoch carries the same
    /// token multiset — see [`EpochPolicy`]).
    ///
    /// ```
    /// use chronicals::session::{DataSource, SessionBuilder};
    ///
    /// let mut session = SessionBuilder::new()
    ///     .steps(4)
    ///     .lr(5e-3)
    ///     .data(DataSource::synthetic(64, 42, 48))
    ///     .shuffle_seed(7) // deterministic: same seed ⇒ same batch order
    ///     .build()?;
    /// assert_eq!(session.run()?.summary.steps, 4);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn shuffle_seed(mut self, seed: u64) -> Self {
        self.epoch_policy.shuffle = Some(seed);
        self
    }

    /// Run exactly `n` passes over the data instead of cycling to
    /// [`SessionBuilder::steps`]: the run length becomes
    /// `n × batches-per-epoch` and the lr schedule spans it.
    ///
    /// ```
    /// use chronicals::session::{DataSource, SessionBuilder};
    ///
    /// let mut session = SessionBuilder::new()
    ///     .lr(5e-3)
    ///     .data(DataSource::synthetic(32, 42, 48))
    ///     .epochs(2)
    ///     .shuffle_seed(11)
    ///     .build()?;
    /// let report = session.run()?;
    /// assert_eq!(report.epochs, 2);
    /// // two identical passes' worth of steps, derived from the plan
    /// assert_eq!(report.summary.steps as usize, report.batches_planned);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn epochs(mut self, n: u64) -> Self {
        self.epoch_policy.epochs = Some(n);
        self
    }

    /// Set the whole shuffle/epoch policy at once.
    pub fn epoch_policy(mut self, policy: EpochPolicy) -> Self {
        self.epoch_policy = policy;
        self
    }

    /// Select which token positions the loss supervises (file-backed
    /// sources; default [`LossMode::ResponseOnly`]).
    ///
    /// ```
    /// use chronicals::session::{DataSource, LossMode, SessionBuilder};
    ///
    /// let path = std::env::temp_dir().join("chronicals_lm_doc.jsonl");
    /// std::fs::write(&path, "{\"prompt\": \"two and two .\", \"completion\": \"four\"}\n")?;
    /// let mut session = SessionBuilder::new()
    ///     .steps(1)
    ///     .lr(5e-3)
    ///     .data(DataSource::jsonl(path.to_str().unwrap(), 7, 64))
    ///     .loss_mode(LossMode::Full) // supervise the prompt too
    ///     .build()?;
    /// assert!(session.run()?.summary.last_loss.is_finite());
    /// # std::fs::remove_file(&path).ok();
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn loss_mode(mut self, mode: LossMode) -> Self {
        self.loss_mode = mode;
        self
    }

    /// Hold out a seeded fraction of the examples for periodic
    /// forward-only eval: the split is driven by [`SessionBuilder::seed`]
    /// alone, so it is disjoint from the train set and bitwise-stable
    /// under any `shuffle_seed`/`epochs` setting.
    ///
    /// ```
    /// use chronicals::session::{DataSource, SessionBuilder};
    ///
    /// let mut session = SessionBuilder::new()
    ///     .steps(4)
    ///     .lr(5e-3)
    ///     .data(DataSource::synthetic(64, 42, 48))
    ///     .eval_fraction(0.25)
    ///     .build()?;
    /// let report = session.run()?;
    /// assert_eq!(report.eval_examples, 16);
    /// assert!(report.final_eval_loss.unwrap().is_finite());
    /// // series: eval before training, at interval points, and at the end
    /// assert_eq!(report.eval.first().unwrap().0, 0);
    /// assert_eq!(report.eval.last().unwrap().0, 4);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn eval_fraction(mut self, fraction: f64) -> Self {
        self.eval_fraction = Some(fraction);
        self
    }

    /// Select the backend by spec (created at build time).
    pub fn backend(mut self, backend: BackendSpec) -> Self {
        self.backend_spec = backend;
        self
    }

    /// Run on an already-constructed backend (tests, benches, sharing one
    /// backend across sessions). Overrides [`SessionBuilder::backend`].
    pub fn on_backend(mut self, backend: Arc<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Run data-parallel over `n` backend replicas: each batch is sharded
    /// row-wise across the replicas and their gradients combine through a
    /// fixed-order reduction tree before one optimizer step, so the loss /
    /// grad-norm / eval series are **bitwise identical for every worker
    /// count** (DESIGN.md §10). `n = 1` still goes through the sharded
    /// path; `0` (the default) is the legacy single-backend path.
    ///
    /// ```
    /// use chronicals::session::{DataSource, SessionBuilder};
    ///
    /// let run = |workers: usize| -> anyhow::Result<f32> {
    ///     let mut s = SessionBuilder::new()
    ///         .steps(3)
    ///         .lr(5e-3)
    ///         .data(DataSource::synthetic(64, 42, 48))
    ///         .workers(workers)
    ///         .build()?;
    ///     Ok(s.run()?.summary.last_loss)
    /// };
    /// // worker count only changes who computes which row, never the bits
    /// assert_eq!(run(1)?.to_bits(), run(2)?.to_bits());
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    pub fn steps(mut self, steps: u64) -> Self {
        self.steps = steps;
        self
    }

    /// Throughput-meter warmup steps excluded from tokens/sec.
    pub fn meter_warmup(mut self, steps: usize) -> Self {
        self.meter_warmup = steps;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn lr(mut self, lr: f64) -> Self {
        self.lr = lr;
        self
    }

    /// LoRA+ ratio λ; composes with the task at build time (a [`Task::Lora`]
    /// task becomes [`Task::LoraPlus`]). Setting it on a non-LoRA task is a
    /// build error.
    pub fn lora_plus_ratio(mut self, ratio: f64) -> Self {
        self.lora_plus_ratio = Some(ratio);
        self
    }

    /// Memory tier 1: hold the AdamW m/v slots in the given codec
    /// ([`OptimStates::Int8`] shrinks optimizer memory ≥3.5× via
    /// Kahan-compensated block quantization; default fp32).
    ///
    /// ```
    /// use chronicals::quant::OptimStates;
    /// use chronicals::session::{DataSource, SessionBuilder};
    ///
    /// let mut session = SessionBuilder::new()
    ///     .steps(2)
    ///     .lr(5e-3)
    ///     .data(DataSource::synthetic(64, 42, 48))
    ///     .optim_states(OptimStates::Int8)
    ///     .build()?;
    /// assert!(session.run()?.summary.last_loss.is_finite());
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn optim_states(mut self, codec: OptimStates) -> Self {
        self.optim_states = codec;
        self
    }

    /// Memory tier 2: quantize the frozen base weights of a LoRA-family
    /// task to the given codec; kernels dequantize per tile inside the
    /// loop, never materializing a dense f32 copy. A build error on tasks
    /// that train the base.
    pub fn base_quant(mut self, codec: BaseQuant) -> Self {
        self.base_quant = Some(codec);
        self
    }

    /// Memory tier 3: segment-level activation checkpointing — keep only
    /// `n` segment-boundary activations in forward and recompute the
    /// interior during backward (0 = off). Bitwise identical to the
    /// uncheckpointed run; costs one extra forward pass over the interior.
    pub fn ckpt_segments(mut self, n: usize) -> Self {
        self.ckpt_segments = n;
        self
    }

    /// Validate and produce the plain-data spec.
    pub fn build_spec(self) -> Result<SessionSpec> {
        let task = match (self.task, self.lora_plus_ratio) {
            (t, None) => t,
            (Task::Lora { rank }, Some(r)) | (Task::LoraPlus { rank, .. }, Some(r)) => {
                Task::LoraPlus { rank, ratio: r }
            }
            (Task::Custom { executable, init, .. }, Some(r)) => {
                Task::Custom { executable, init, lora_plus_ratio: r }
            }
            (t, Some(r)) if (r - 1.0).abs() < 1e-12 => t, // λ=1 is "off"
            (t, Some(r)) => bail!("LoRA+ ratio λ={r} requires a LoRA task ({t} is not one)"),
        };
        let seed = self.seed;
        let data = self
            .data
            .unwrap_or(DataSource::Synthetic { examples: 2048, seed, max_seq: 1024 });
        let spec = SessionSpec {
            task,
            schedule: self.schedule,
            packing: self.packing,
            data,
            epoch_policy: self.epoch_policy,
            loss_mode: self.loss_mode,
            eval_fraction: self.eval_fraction,
            backend: self.backend_spec,
            workers: self.workers,
            steps: self.steps,
            meter_warmup: self.meter_warmup,
            seed,
            lr: self.lr,
            optim_states: self.optim_states,
            base_quant: self.base_quant,
            ckpt_segments: self.ckpt_segments,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Validate, create (or adopt) the backend, resolve the task against
    /// its manifest and initialize training state.
    pub fn build(mut self) -> Result<Session> {
        let backend = self.backend.take();
        let spec = self.build_spec()?;
        match backend {
            Some(_) if spec.workers > 0 => {
                // an adopted backend is a single instance; data-parallel
                // needs to construct one replica per worker from the spec
                bail!(
                    "workers({}) cannot be combined with on_backend(): replicas \
                     are created from the backend spec — use .backend(...) instead",
                    spec.workers
                )
            }
            Some(be) => Session::with_backend(spec, be),
            None => spec.build(),
        }
    }
}

/// Everything a run reports: the training summary plus the data-pipeline
/// accounting that used to be lost silently.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub summary: TrainSummary,
    /// Examples the data source produced.
    pub examples: usize,
    /// Examples skipped by the packing plan because they exceed the row
    /// capacity `S` (paper Alg. 16 "skip oversized"). Zero for `Padded`
    /// (it truncates instead).
    pub oversized_dropped: usize,
    /// Batches staged on the backend. In cycle mode this is the distinct
    /// batch count (≤ steps; staged batches are reused when the corpus is
    /// shorter than the run); in epoch mode every emitted batch is staged
    /// (shuffling can change batch composition per epoch).
    pub batches_staged: usize,
    /// Batches the plan emits in total, across every epoch.
    pub batches_planned: usize,
    /// Whether each epoch's final batch carries empty padding rows (the
    /// partial tail is padded, not dropped — no example is lost).
    pub tail_padded: bool,
    /// Data passes the run made (1 in legacy cycle mode).
    pub epochs: u64,
    /// Records the data source skipped as malformed (JSON syntax or schema
    /// errors; `file:line` details in [`RunReport::source_notes`]). Always
    /// zero for the synthetic corpus.
    pub malformed_skipped: usize,
    /// Records the data source truncated to its `max_seq` token cap.
    pub truncated: usize,
    /// First few per-record diagnostics from the data source.
    pub source_notes: Vec<String>,
    /// Fraction of `[B, S]` slots holding real tokens across one epoch of
    /// the plan (paper Fig. 18's packing efficiency, tail padding
    /// included).
    pub packed_density: f64,
    /// Fraction of the padded baseline's padding waste that packing
    /// recovered: 0 for `Padded`, 0.6–0.75 is the paper's BFD claim on
    /// Alpaca-shaped length distributions (Prop. 14).
    pub padding_recovery: f64,
    /// Held-out eval loss series `(step, loss)`: one entry before training
    /// (step 0), at periodic interval points, and after the final step.
    /// Empty when no eval fraction is set.
    pub eval: Vec<(u64, f32)>,
    /// The last entry of [`RunReport::eval`]; `None` when eval is off.
    pub final_eval_loss: Option<f32>,
    /// Examples held out of training for the eval split (0 = eval off).
    pub eval_examples: usize,
}

/// Domain-separation salt for the eval split's RNG: the split must not
/// correlate with any other consumer of the run seed (corpus generation,
/// init, plan shuffling).
const EVAL_SPLIT_SALT: u64 = 0x5EED_E7A1_0F5E_11D5;

/// The deterministic held-out split: Fisher–Yates over `0..n` seeded by
/// `seed` alone, then the first ⌊n·fraction⌋ indices (clamped to keep at
/// least one example on each side) become the eval set. Returns
/// `(train_indices, eval_indices)`, each sorted ascending — together they
/// partition `0..n`, and the same `(n, fraction, seed)` always produces the
/// same split regardless of shuffle/epoch settings (DESIGN.md §9).
pub fn eval_split(n: usize, fraction: f64, seed: u64) -> (Vec<usize>, Vec<usize>) {
    assert!(n >= 2, "an eval split needs at least 2 examples (got {n})");
    let mut idx: Vec<usize> = (0..n).collect();
    Rng::new(seed ^ EVAL_SPLIT_SALT).shuffle(&mut idx);
    let n_eval = ((n as f64 * fraction).floor() as usize).clamp(1, n - 1);
    let mut eval: Vec<usize> = idx[..n_eval].to_vec();
    let mut train: Vec<usize> = idx[n_eval..].to_vec();
    eval.sort_unstable();
    train.sort_unstable();
    (train, eval)
}

/// Weighted mean eval loss over a fixed batch set: each batch's mean loss
/// weighted by its supervised-target count, so padding rows and short tail
/// batches do not skew the aggregate.
fn eval_pass(trainer: &Trainer, eval_exe: &str, batches: &[Batch]) -> Result<f32> {
    let mut num = 0.0f64;
    let mut den = 0usize;
    for b in batches {
        let loss = trainer.eval(eval_exe, b)?;
        num += loss as f64 * b.real_targets as f64;
        den += b.real_targets;
    }
    if den == 0 {
        bail!("eval batches hold no supervised targets");
    }
    Ok((num / den as f64) as f32)
}

/// A built, runnable training session: backend + resolved executables +
/// trainer, driving the lazy batch stream.
pub struct Session {
    spec: SessionSpec,
    backend: Arc<dyn Backend>,
    resolved: Resolved,
    trainer: Trainer,
}

impl Session {
    /// Build on an explicit backend instance (ignores `spec.backend`).
    pub fn with_backend(spec: SessionSpec, backend: Arc<dyn Backend>) -> Result<Session> {
        spec.validate()?;
        let resolved = resolve::resolve(backend.manifest(), &spec.task)?;
        let schedule = spec.schedule.lr_schedule(spec.lr, spec.steps, resolved.lora_plus_ratio);
        let mut state = backend.init_state(&resolved.init, spec.seed as i32)?;
        // push the memory tiers onto the fresh state before any step runs:
        // the optimizer-state codec can only change while slots are zero,
        // and base quantization must precede the first forward
        let mem = spec.memory_cfg();
        if !mem.is_default() {
            backend.configure_memory(&mut state, &mem)?;
        }
        let trainer =
            Trainer::new(backend.clone(), &resolved.train, state, schedule, spec.meter_warmup)?;
        Ok(Session { spec, backend, resolved, trainer })
    }

    pub fn spec(&self) -> &SessionSpec {
        &self.spec
    }

    /// The manifest wiring this session resolved to.
    pub fn resolved(&self) -> &Resolved {
        &self.resolved
    }

    pub fn backend(&self) -> &Arc<dyn Backend> {
        &self.backend
    }

    /// Per-step records (loss curve, grad norms) accumulated so far.
    pub fn records(&self) -> &[StepRecord] {
        &self.trainer.records
    }

    /// Direct access to the underlying trainer (eval, manual stepping).
    pub fn trainer_mut(&mut self) -> &mut Trainer {
        &mut self.trainer
    }

    /// Save current parameters to a checkpoint file.
    pub fn save_checkpoint(&self, path: impl AsRef<Path>, codec: Codec) -> Result<()> {
        self.trainer.save_checkpoint(path, codec)
    }

    /// Run the session: tokenize → pack → stream batches lazily under the
    /// [`EpochPolicy`]. In cycle mode (the default) each distinct batch is
    /// staged on the backend once and staged batches are cycled when the
    /// stream is exhausted; in epoch mode the stream emits exactly
    /// `epochs` (optionally shuffled) passes over the plan and the run
    /// length follows the data. The tail batch is padded, never dropped
    /// ([`TailPolicy::Pad`]).
    pub fn run(&mut self) -> Result<RunReport> {
        let exe = &self.resolved.spec;
        // vocab cap = the model's vocab so token ids stay in range
        let vocab = exe.model_config.vocab.max(64);
        let (batch, seq) = (exe.batch, exe.seq);
        let (mut examples, source) = self.spec.data.tokenized(vocab, self.spec.loss_mode)?;
        let n_examples = examples.len();
        // seeded held-out split: disjoint from the train set and stable
        // under shuffle/epoch settings (it depends on spec.seed alone)
        let mut eval_ctx: Option<(String, Vec<Batch>)> = None;
        let mut eval_examples = 0usize;
        if let Some(f) = self.spec.eval_fraction {
            if n_examples < 2 {
                bail!(
                    "eval fraction needs at least 2 usable examples, {} has {n_examples}",
                    self.spec.data.label()
                );
            }
            let (_, eval_idx) = eval_split(n_examples, f, self.spec.seed);
            eval_examples = eval_idx.len();
            let mut in_eval = vec![false; n_examples];
            for &i in &eval_idx {
                in_eval[i] = true;
            }
            let mut train_set = Vec::with_capacity(n_examples - eval_examples);
            let mut eval_set = Vec::with_capacity(eval_examples);
            for (i, ex) in examples.into_iter().enumerate() {
                if in_eval[i] {
                    eval_set.push(ex);
                } else {
                    train_set.push(ex);
                }
            }
            examples = train_set;
            let eval_exe = resolve_eval(self.backend.manifest(), &self.resolved.train)?;
            let eval_batches: Vec<Batch> =
                BatchStream::new(eval_set, self.spec.packing, batch, seq, TailPolicy::Pad)
                    .collect();
            if eval_batches.is_empty() {
                bail!(
                    "the eval split ({eval_examples} examples) produced no batches — \
                     lower the eval fraction or raise max_seq"
                );
            }
            eval_ctx = Some((eval_exe, eval_batches));
        }
        let n_train = examples.len();
        // padded-baseline accounting (one row per example) for the
        // padding-recovery report — over the example set the plan actually
        // packs: packing strategies skip oversized examples, the padded
        // layout truncates them, so the baseline must match or the two
        // waste figures would cover different corpora
        let (padded_rows, padded_tokens) = {
            let lens = examples.iter().map(|e| e.len());
            match self.spec.packing {
                PackingStrategy::Padded => {
                    (n_train, lens.map(|l| l.min(seq)).sum::<usize>())
                }
                _ => {
                    let packable: Vec<usize> = lens.filter(|&l| l <= seq).collect();
                    (packable.len(), packable.iter().sum::<usize>())
                }
            }
        };
        let policy = self.spec.epoch_policy;
        let epochs = policy.epochs.unwrap_or(1);
        let mut stream = BatchStream::with_epochs(
            examples,
            self.spec.packing,
            batch,
            seq,
            TailPolicy::Pad,
            EpochSpec { shuffle: policy.shuffle, epochs },
        );
        if stream.n_batches() == 0 {
            bail!(
                "no batches for '{}' (B={batch}, S={seq}, {n_train} train examples from {})",
                self.resolved.train,
                self.spec.data.label()
            );
        }
        let batches_planned = stream.n_batches();
        let per_epoch = stream.batches_per_epoch();
        let oversized_dropped = stream.oversized_dropped();
        let tail_padded = stream.tail_padded();
        // plan-level density + recovery (shuffling permutes the plan, so
        // both are identical for every epoch)
        let packed_tokens = stream.planned_tokens();
        let packed_density = packed_tokens as f64 / (per_epoch * batch * seq) as f64;
        let padding_recovery = if padded_rows == 0 {
            0.0
        } else {
            let waste_padded = 1.0 - padded_tokens as f64 / (padded_rows * seq) as f64;
            let waste_packed = 1.0 - packed_tokens as f64 / (stream.n_bins() * seq) as f64;
            if waste_padded <= 0.0 {
                0.0
            } else {
                ((waste_padded - waste_packed) / waste_padded).clamp(0.0, 1.0)
            }
        };

        // periodic eval points: before training (step 0), every interval
        // (each epoch boundary in epoch mode, quarters of the run in cycle
        // mode) and after the final step
        let total_steps =
            if policy.epochs.is_some() { batches_planned as u64 } else { self.spec.steps };
        let eval_interval = if policy.epochs.is_some() {
            per_epoch as u64
        } else {
            (total_steps / 4).max(1)
        };
        let mut eval_series: Vec<(u64, f32)> = Vec::new();
        if let Some((eval_exe, eb)) = &eval_ctx {
            eval_series.push((0, eval_pass(&self.trainer, eval_exe, eb)?));
        }

        let mut staged: Vec<DeviceBatch> = Vec::new();
        let batches_staged;
        if policy.epochs.is_some() {
            // epoch mode: the run length follows the data, so rebuild the
            // lr schedule to span it before the first step
            let total = batches_planned as u64;
            if let Schedule::WarmupCosine { warmup } = self.spec.schedule {
                if warmup >= total {
                    bail!(
                        "lr warmup ({warmup} steps) must be shorter than the epoch run \
                         ({total} steps = {epochs} epochs × {per_epoch} batches)"
                    );
                }
            }
            self.trainer.set_schedule(self.spec.schedule.lr_schedule(
                self.spec.lr,
                total,
                self.resolved.lora_plus_ratio,
            ));
            if policy.shuffle.is_none() {
                // unshuffled epochs are bitwise-identical passes: stage one
                // epoch and replay it, exactly like the cycle path
                for b in stream.by_ref().take(per_epoch) {
                    staged.push(self.trainer.upload_batch(&b)?);
                }
                for i in 0..total {
                    let idx = (i % per_epoch as u64) as usize;
                    self.trainer.step_uploaded(&staged[idx])?;
                    let s = i + 1;
                    if let Some((eval_exe, eb)) = &eval_ctx {
                        if s == total_steps || s % eval_interval == 0 {
                            eval_series.push((s, eval_pass(&self.trainer, eval_exe, eb)?));
                        }
                    }
                }
                batches_staged = staged.len();
            } else {
                // every emitted batch is staged: under a shuffle seed the
                // batch composition itself changes per epoch
                let mut uploads = 0usize;
                for b in stream {
                    let ub = self.trainer.upload_batch(&b)?;
                    uploads += 1;
                    self.trainer.step_uploaded(&ub)?;
                    let s = uploads as u64;
                    if let Some((eval_exe, eb)) = &eval_ctx {
                        if s == total_steps || s % eval_interval == 0 {
                            eval_series.push((s, eval_pass(&self.trainer, eval_exe, eb)?));
                        }
                    }
                }
                batches_staged = uploads;
            }
        } else {
            for i in 0..self.spec.steps {
                match stream.next() {
                    Some(b) => {
                        staged.push(self.trainer.upload_batch(&b)?);
                        let ub = staged.last().expect("just pushed");
                        self.trainer.step_uploaded(ub)?;
                    }
                    None => {
                        let idx = (i % staged.len() as u64) as usize;
                        self.trainer.step_uploaded(&staged[idx])?;
                    }
                }
                let s = i + 1;
                if let Some((eval_exe, eb)) = &eval_ctx {
                    if s == total_steps || s % eval_interval == 0 {
                        eval_series.push((s, eval_pass(&self.trainer, eval_exe, eb)?));
                    }
                }
            }
            batches_staged = staged.len();
        }
        let final_eval_loss = eval_series.last().map(|&(_, l)| l);
        Ok(RunReport {
            summary: self.trainer.summary(),
            examples: n_examples,
            oversized_dropped,
            batches_staged,
            batches_planned,
            tail_padded,
            epochs,
            malformed_skipped: source.malformed,
            truncated: source.truncated,
            source_notes: source.notes,
            packed_density,
            padding_recovery,
            eval: eval_series,
            final_eval_loss,
            eval_examples,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_validate() {
        let spec = SessionBuilder::new().build_spec().unwrap();
        assert_eq!(spec.task, Task::FullFinetune);
        assert_eq!(spec.packing, PackingStrategy::Bfd);
        assert_eq!(spec.steps, 50);
    }

    #[test]
    fn zero_steps_rejected() {
        let err = SessionBuilder::new().steps(0).build_spec().unwrap_err();
        assert!(err.to_string().contains("steps"), "{err}");
    }

    #[test]
    fn warmup_longer_than_run_rejected() {
        let err = SessionBuilder::new()
            .steps(10)
            .schedule(Schedule::WarmupCosine { warmup: 10 })
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("warmup"), "{err}");
    }

    #[test]
    fn ratio_on_non_lora_task_rejected() {
        let err = SessionBuilder::new()
            .task(Task::FullFinetune)
            .lora_plus_ratio(16.0)
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("LoRA"), "{err}");
        // λ=1 means "off" and is accepted everywhere
        assert!(SessionBuilder::new()
            .task(Task::FullFinetune)
            .lora_plus_ratio(1.0)
            .build_spec()
            .is_ok());
    }

    #[test]
    fn ratio_composes_with_lora_task() {
        let spec = SessionBuilder::new()
            .task(Task::lora())
            .lora_plus_ratio(16.0)
            .build_spec()
            .unwrap();
        assert_eq!(spec.task, Task::LoraPlus { rank: None, ratio: 16.0 });
    }

    #[test]
    fn nonpositive_ratio_rejected() {
        let err = SessionBuilder::new().task(Task::lora_plus(0.0)).build_spec().unwrap_err();
        assert!(err.to_string().contains("positive"), "{err}");
    }

    #[test]
    fn base_quant_on_base_training_task_rejected() {
        let err = SessionBuilder::new()
            .task(Task::FullFinetune)
            .base_quant(BaseQuant::Int8)
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("LoRA"), "{err}");
        // LoRA freezes the base, so quantizing it is fine
        let spec = SessionBuilder::new()
            .task(Task::lora())
            .base_quant(BaseQuant::Fp8)
            .build_spec()
            .unwrap();
        assert_eq!(spec.base_quant, Some(BaseQuant::Fp8));
        assert!(!spec.memory_cfg().is_default());
    }

    #[test]
    fn memory_tier_defaults_are_legacy() {
        let spec = SessionBuilder::new().build_spec().unwrap();
        assert_eq!(spec.optim_states, OptimStates::Fp32);
        assert_eq!(spec.base_quant, None);
        assert_eq!(spec.ckpt_segments, 0);
        assert!(spec.memory_cfg().is_default());
    }

    #[test]
    fn memory_tiers_lower_from_run_config() {
        let mut cfg = RunConfig::default();
        cfg.executable = "train_step_lora".into();
        cfg.optim_states = "int8".into();
        cfg.base_quant = "int8".into();
        cfg.ckpt_segments = 2;
        let spec = SessionSpec::from_run_config(&cfg).unwrap();
        assert_eq!(spec.optim_states, OptimStates::Int8);
        assert_eq!(spec.base_quant, Some(BaseQuant::Int8));
        assert_eq!(spec.ckpt_segments, 2);
        // "none" and empty both mean dense
        cfg.base_quant = "none".into();
        assert_eq!(SessionSpec::from_run_config(&cfg).unwrap().base_quant, None);
        // unknown codec names are real errors
        cfg.base_quant = "int3".into();
        assert!(SessionSpec::from_run_config(&cfg).is_err());
        cfg.base_quant = String::new();
        cfg.optim_states = "bf16".into();
        assert!(SessionSpec::from_run_config(&cfg).is_err());
    }

    #[test]
    fn session_runs_all_three_tiers_end_to_end() {
        let mut session = SessionBuilder::new()
            .task(Task::lora())
            .steps(3)
            .lr(5e-3)
            .data(DataSource::synthetic(32, 42, 48))
            .optim_states(OptimStates::Int8)
            .base_quant(BaseQuant::Int8)
            .ckpt_segments(2)
            .build()
            .unwrap();
        let report = session.run().unwrap();
        assert_eq!(report.summary.steps, 3);
        assert!(report.summary.last_loss.is_finite());
        assert!(report.summary.verification.is_training);
    }

    #[test]
    fn zero_epochs_rejected() {
        let err = SessionBuilder::new().epochs(0).build_spec().unwrap_err();
        assert!(err.to_string().contains("epochs"), "{err}");
    }

    #[test]
    fn builder_composes_epoch_policy() {
        let spec = SessionBuilder::new().shuffle_seed(7).epochs(2).build_spec().unwrap();
        assert_eq!(spec.epoch_policy, EpochPolicy { shuffle: Some(7), epochs: Some(2) });
        // default stays bitwise-legacy
        let d = SessionBuilder::new().build_spec().unwrap();
        assert_eq!(d.epoch_policy, EpochPolicy::default());
    }

    #[test]
    fn eval_fraction_bounds_rejected_at_build() {
        for bad in [0.0, -0.25, f64::NAN] {
            let err = SessionBuilder::new().eval_fraction(bad).build_spec().unwrap_err();
            assert!(
                err.to_string().contains("positive and finite"),
                "fraction {bad}: {err}"
            );
        }
        for bad in [1.0, 1.5, 7.0] {
            let err = SessionBuilder::new().eval_fraction(bad).build_spec().unwrap_err();
            assert!(
                err.to_string().contains("at least one example trains"),
                "fraction {bad}: {err}"
            );
        }
        let spec = SessionBuilder::new().eval_fraction(0.2).build_spec().unwrap();
        assert_eq!(spec.eval_fraction, Some(0.2));
        // default: no eval split, response-only loss
        let d = SessionBuilder::new().build_spec().unwrap();
        assert_eq!(d.eval_fraction, None);
        assert_eq!(d.loss_mode, LossMode::ResponseOnly);
    }

    #[test]
    fn eval_split_is_a_stable_disjoint_partition() {
        let (train, eval) = eval_split(100, 0.2, 42);
        assert_eq!(eval.len(), 20);
        assert_eq!(train.len(), 80);
        let mut union: Vec<usize> = train.iter().chain(&eval).copied().collect();
        union.sort_unstable();
        assert_eq!(union, (0..100).collect::<Vec<_>>(), "partition of 0..n");
        // bitwise stable across calls; seed-driven
        assert_eq!(eval_split(100, 0.2, 42), (train, eval));
        assert_ne!(eval_split(100, 0.2, 43).1, eval_split(100, 0.2, 42).1);
        // clamped to keep both sides non-empty
        let (t, e) = eval_split(2, 0.01, 7);
        assert_eq!((t.len(), e.len()), (1, 1));
        let (t, e) = eval_split(10, 0.99, 7);
        assert_eq!((t.len(), e.len()), (1, 9));
    }

    #[test]
    fn chat_source_validation() {
        let err = SessionBuilder::new()
            .data(DataSource::chat("", 1, 64))
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("file path"), "{err}");
        let err = SessionBuilder::new()
            .data(DataSource::chat("x.jsonl", 1, 0))
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("max_seq"), "{err}");
        assert_eq!(DataSource::chat("x.jsonl", 1, 64).label(), "chat(x.jsonl)");
    }

    #[test]
    fn jsonl_source_validation() {
        let err = SessionBuilder::new()
            .data(DataSource::jsonl("", 1, 64))
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("file path"), "{err}");
        let err = SessionBuilder::new()
            .data(DataSource::jsonl("x.jsonl", 1, 0))
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("max_seq"), "{err}");
    }

    #[test]
    fn empty_corpus_rejected() {
        let err = SessionBuilder::new()
            .data(DataSource::synthetic(0, 1, 64))
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("example"), "{err}");
    }

    #[test]
    fn unknown_backend_name_rejected() {
        assert!(BackendSpec::parse("tpu", "", 0).is_err());
    }

    #[test]
    fn task_parse_cli_names() {
        assert_eq!(Task::parse("full-ft", None, None).unwrap(), Task::FullFinetune);
        assert_eq!(
            Task::parse("lora-plus", None, None).unwrap(),
            Task::LoraPlus { rank: None, ratio: 16.0 }
        );
        assert_eq!(
            Task::parse("lora", Some(4), Some(8.0)).unwrap(),
            Task::LoraPlus { rank: Some(4), ratio: 8.0 }
        );
        assert!(Task::parse("full-ft", None, Some(16.0)).is_err());
        assert!(Task::parse("ablate-naive", Some(4), None).is_err());
        assert!(Task::parse("frobnicate", None, None).is_err());
    }

    #[test]
    fn workers_default_is_legacy_path() {
        let spec = SessionBuilder::new().build_spec().unwrap();
        assert_eq!(spec.workers, 0);
    }

    #[test]
    fn workers_validation() {
        let spec = SessionBuilder::new().workers(4).build_spec().unwrap();
        assert_eq!(spec.workers, 4);
        let err = SessionBuilder::new()
            .workers(2)
            .backend(BackendSpec::Pjrt { artifacts_dir: "x".into() })
            .build_spec()
            .unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
        let err = SessionBuilder::new().workers(65).build_spec().unwrap_err();
        assert!(err.to_string().contains("64"), "{err}");
    }

    #[test]
    fn workers_with_adopted_backend_rejected() {
        let be: Arc<dyn Backend> = Arc::new(crate::backend::cpu::CpuBackend::new());
        let err = SessionBuilder::new().workers(2).on_backend(be).build().unwrap_err();
        assert!(err.to_string().contains("on_backend"), "{err}");
    }

    #[test]
    fn workers_spec_builds_data_parallel_backend() {
        let spec = SessionBuilder::new().workers(3).build_spec().unwrap();
        let be = spec.create_backend().unwrap();
        assert_eq!(be.name(), "data-parallel");
        // legacy path untouched when workers are unset
        let spec = SessionBuilder::new().build_spec().unwrap();
        assert_eq!(spec.create_backend().unwrap().name(), "cpu");
    }

    #[test]
    fn schedule_parse_names() {
        assert_eq!(Schedule::parse("constant", 0).unwrap(), Schedule::Constant);
        assert_eq!(
            Schedule::parse("warmup-cosine", 5).unwrap(),
            Schedule::WarmupCosine { warmup: 5 }
        );
        assert!(Schedule::parse("linear", 0).is_err());
    }
}
