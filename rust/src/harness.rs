//! Shared benchmark harness: the workflows behind `chronicals bench`, the
//! `benches/` binaries and the examples. Each function regenerates one of
//! the paper's tables/figures from live measurements (DESIGN.md §5), and
//! every workflow is backend-agnostic: pass any [`Backend`] — the CPU
//! reference gives deterministic CI-runnable numbers, PJRT gives the real
//! artifact measurements.
//!
//! All training workflows go through the typed [`crate::session`] API —
//! the table generators below hold [`Task`]s, not executable-name strings
//! (those exist only behind `session::resolve`).

use crate::backend::Backend;
use crate::batching::{Batch, BatchStream, PackingStrategy, TailPolicy};
use crate::config::RunConfig;
use crate::coordinator::TrainSummary;
use crate::data::{TokenizedExample, Tokenizer};
use crate::manifest::Manifest;
use crate::report::{self, Row};
use crate::session::{Session, SessionBuilder, SessionSpec, Task};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Build the tokenized corpus once per (seed, size, vocab cap). Thin
/// re-export of [`crate::data::build_corpus`] kept for the test suites.
pub fn build_corpus(
    n_examples: usize,
    seed: u64,
    vocab_cap: usize,
    max_seq: usize,
) -> (Tokenizer, Vec<TokenizedExample>) {
    crate::data::build_corpus(n_examples, seed, vocab_cap, max_seq)
}

/// Make batches for a given executable spec + packing choice. Eager helper
/// for tests and manual trainer driving; unlike the legacy version, the
/// trailing partial batch is padded, not silently dropped (the session
/// pipeline's [`TailPolicy::Pad`]).
pub fn make_batches(
    manifest: &Manifest,
    exe_name: &str,
    examples: &[TokenizedExample],
    packed: bool,
) -> Result<Vec<Batch>> {
    let spec = manifest.get(exe_name)?;
    let (b, s) = (spec.batch, spec.seq);
    let strategy = if packed { PackingStrategy::Bfd } else { PackingStrategy::Padded };
    let batches: Vec<Batch> =
        BatchStream::new(examples.to_vec(), strategy, b, s, TailPolicy::Pad).collect();
    if batches.is_empty() {
        return Err(anyhow!(
            "no batches for {exe_name} (B={b}, S={s}, {} examples)",
            examples.len()
        ));
    }
    Ok(batches)
}

/// Run one training configuration end to end, returning the summary row.
/// `RunConfig` is the stringly front-end: it lowers into a typed
/// [`SessionSpec`] and runs on the given backend.
pub fn run_variant(backend: &Arc<dyn Backend>, cfg: &RunConfig) -> Result<TrainSummary> {
    let spec = SessionSpec::from_run_config(cfg)?;
    let mut session = Session::with_backend(spec, backend.clone())?;
    Ok(session.run()?.summary)
}

/// Run one typed table row on a shared backend: a task + packing choice at
/// the harness defaults (2 meter-warmup steps, RunConfig-default corpus).
fn table_row(
    backend: &Arc<dyn Backend>,
    task: Task,
    packing: PackingStrategy,
    steps: u64,
    lr: f64,
) -> Result<(TrainSummary, usize)> {
    let mut session = SessionBuilder::new()
        .task(task)
        .packing(packing)
        .steps(steps)
        .meter_warmup(2)
        .lr(lr)
        .on_backend(backend.clone())
        .build()?;
    let summary = session.run()?.summary;
    let batch = session.resolved().spec.batch;
    Ok((summary, batch))
}

/// Table 4 ablation ladder: run each rung, return report rows.
pub fn ablation_ladder(backend: &Arc<dyn Backend>, steps: u64) -> Result<Vec<Row>> {
    let rungs: Vec<(&str, Task, PackingStrategy)> = vec![
        ("Baseline (eager, padded)", Task::AblateNaive, PackingStrategy::Padded),
        ("+ FlashAttention", Task::AblateFlash, PackingStrategy::Padded),
        ("+ whole-graph compile", Task::AblateCompiled, PackingStrategy::Padded),
        ("+ fused kernels & CCE", Task::AblateLiger, PackingStrategy::Padded),
        ("+ sequence packing", Task::AblateLiger, PackingStrategy::Bfd),
        ("+ fused optimizer", Task::FullFinetune, PackingStrategy::Bfd),
    ];
    let mut rows = Vec::new();
    for (label, task, packing) in rungs {
        let (s, batch) = table_row(backend, task, packing, steps, 2e-4)?;
        rows.push(Row::from_summary(label, "full", batch, &s));
    }
    Ok(rows)
}

/// Table 2: full fine-tuning, naive ("Unsloth-correct"-shaped baseline) vs
/// chronicals, plus the broken "fast mode" row (Fig. 10).
pub fn full_ft_comparison(backend: &Arc<dyn Backend>, steps: u64) -> Result<Vec<Row>> {
    let runs: Vec<(&str, Task, PackingStrategy)> = vec![
        ("Baseline (naive, verified)", Task::AblateNaive, PackingStrategy::Padded),
        ("Chronicals (verified)", Task::FullFinetune, PackingStrategy::Bfd),
    ];
    let mut rows = Vec::new();
    for (label, task, packing) in runs {
        let (s, batch) = table_row(backend, task, packing, steps, 2e-4)?;
        rows.push(Row::from_summary(label, "full", batch, &s));
    }
    Ok(rows)
}

/// Table 3: LoRA naive vs Chronicals LoRA vs LoRA+ (λ=16) vs broken mode.
pub fn lora_comparison(backend: &Arc<dyn Backend>, steps: u64) -> Result<Vec<Row>> {
    let runs: Vec<(&str, Task, PackingStrategy)> = vec![
        ("LoRA naive (Unsloth-shaped)", Task::LoraNaive, PackingStrategy::Padded),
        ("Chronicals LoRA", Task::lora(), PackingStrategy::Bfd),
        ("Chronicals LoRA+ (λ=16)", Task::lora_plus(16.0), PackingStrategy::Bfd),
        ("'Fast mode' (BROKEN)", Task::LoraBroken, PackingStrategy::Bfd),
    ];
    let mut rows = Vec::new();
    for (label, task, packing) in runs {
        let (s, batch) = table_row(backend, task, packing, steps, 1e-3)?;
        rows.push(Row::from_summary(label, "lora", batch, &s));
    }
    Ok(rows)
}

/// Table 5: fused-vs-naive kernel pairs. Supported on `cpu-fast` (its
/// fused/tiled kernels vs the reference scalar implementations on
/// identical inputs) and on PJRT (compiled kernel artifacts). The CPU
/// reference backend has no fused variants and reports a clean error.
pub fn kernel_microbench(backend: &dyn Backend, reps: usize) -> Result<Vec<(String, f64, f64)>> {
    let pairs = [
        ("RMSNorm", "kernel_rmsnorm_fused", "kernel_rmsnorm_naive"),
        ("SwiGLU", "kernel_swiglu_fused", "kernel_swiglu_naive"),
        ("QK-RoPE", "kernel_rope_fused", "kernel_rope_naive"),
        ("Attention", "kernel_attention_flash", "kernel_attention_naive"),
        ("Cross-Entropy", "kernel_cross_entropy_fused", "kernel_cross_entropy_naive"),
        ("AdamW", "kernel_adamw_fused", "kernel_adamw_naive"),
        ("LoRA Linear", "kernel_lora_linear_fused", "kernel_lora_linear_naive"),
    ];
    let mut out = Vec::new();
    for (label, fused, naive) in pairs {
        let tf = backend.bench_kernel(fused, reps, 2)?;
        let tn = backend.bench_kernel(naive, reps, 2)?;
        out.push((label.to_string(), tf, tn));
    }
    Ok(out)
}

/// Fig. 18 packing analysis over the synthetic corpus.
pub fn packing_report(capacity: usize, n_examples: usize) -> String {
    use crate::packing::*;
    let (_tok, exs) = build_corpus(n_examples, 42, 8192, capacity * 2);
    let lengths: Vec<usize> = exs.iter().map(|e| e.len()).collect();
    let algos: Vec<(&str, Packing)> = vec![
        ("No packing (padded)", no_packing(&lengths, capacity)),
        ("Next-Fit", next_fit(&lengths, capacity)),
        ("First-Fit Decreasing", first_fit_decreasing(&lengths, capacity)),
        ("Best-Fit Decreasing", best_fit_decreasing(&lengths, capacity)),
    ];
    let lb = Packing::opt_lower_bound(&lengths, capacity);
    let mut out = String::new();
    out.push_str(&format!(
        "## Packing (Fig. 18) — {} sequences, capacity {}, OPT ≥ {}\n",
        lengths.len(),
        capacity,
        lb
    ));
    out.push_str(&format!(
        "| {:<24} | {:>7} | {:>10} | {:>8} |\n|{}|\n",
        "Algorithm", "Bins", "Efficiency", "vs OPT",
        "-".repeat(60)
    ));
    for (name, p) in &algos {
        out.push_str(&format!(
            "| {:<24} | {:>7} | {:>9.1}% | {:>7.3}x |\n",
            name,
            p.n_bins(),
            p.efficiency() * 100.0,
            p.n_bins() as f64 / lb as f64
        ));
    }
    out
}

/// Render the full `bench --summary` report.
pub fn summary_report(backend: &Arc<dyn Backend>, steps: u64) -> Result<String> {
    let mut out = String::new();
    let full = full_ft_comparison(backend, steps)?;
    out.push_str(&report::throughput_table(
        "Full fine-tuning (paper Table 2)",
        &full,
        "Baseline (naive, verified)",
    ));
    out.push('\n');
    let lora = lora_comparison(backend, steps)?;
    out.push_str(&report::throughput_table(
        "LoRA r=32 (paper Table 3)",
        &lora,
        "LoRA naive (Unsloth-shaped)",
    ));
    out.push('\n');
    let ladder = ablation_ladder(backend, steps)?;
    out.push_str(&report::ablation_table(&ladder));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;

    #[test]
    fn kernel_microbench_errors_cleanly_on_cpu() {
        let be = CpuBackend::new();
        let err = kernel_microbench(&be, 1).unwrap_err();
        assert!(err.to_string().contains("not supported"), "{err}");
    }

    #[test]
    fn kernel_microbench_runs_on_cpu_fast() {
        let be = crate::backend::cpu_fast::FastCpuBackend::with_threads(1);
        let rows = kernel_microbench(&be, 1).unwrap();
        assert_eq!(rows.len(), 7, "all Table-5 kernel pairs must time");
        for (name, fused, naive) in rows {
            assert!(fused > 0.0 && naive > 0.0, "{name}: {fused} vs {naive}");
        }
    }

    #[test]
    fn make_batches_pads_the_tail_instead_of_dropping() {
        let be = CpuBackend::new();
        let spec = be.manifest().get("train_step_chronicals").unwrap().clone();
        // 13 examples of ≤ 8 tokens in 64-token bins: BFD packs several per
        // bin; whatever the bin count, no token may vanish
        let exs: Vec<TokenizedExample> = (0..13)
            .map(|i| TokenizedExample {
                tokens: vec![4 + i, 5 + i, 6 + i],
                targets: vec![5 + i, 6 + i, -1],
            })
            .collect();
        let batches = make_batches(be.manifest(), "train_step_chronicals", &exs, true).unwrap();
        let total: usize = batches.iter().map(|b| b.real_tokens).sum();
        assert_eq!(total, 13 * 3, "padded tail must keep every example");
        assert_eq!(batches[0].batch, spec.batch);
    }
}
