//! Shared benchmark harness: the workflows behind `chronicals bench`, the
//! `benches/` binaries and the examples. Each function regenerates one of
//! the paper's tables/figures from live measurements (DESIGN.md §5), and
//! every workflow is backend-agnostic: pass any [`Backend`] — the CPU
//! reference gives deterministic CI-runnable numbers, PJRT gives the real
//! artifact measurements.

use crate::backend::Backend;
use crate::batching::{packed_batches, padded_batches, Batch};
use crate::config::RunConfig;
use crate::coordinator::{Trainer, TrainSummary};
use crate::data::{tokenize_corpus, CorpusConfig, SyntheticCorpus, Tokenizer, TokenizedExample};
use crate::manifest::Manifest;
use crate::optim::LrSchedule;
use crate::report::{self, Row};
use anyhow::{anyhow, Result};
use std::rc::Rc;

/// Build the tokenized corpus once per (seed, size, vocab cap).
pub fn build_corpus(
    n_examples: usize,
    seed: u64,
    vocab_cap: usize,
    max_seq: usize,
) -> (Tokenizer, Vec<TokenizedExample>) {
    let cfg = CorpusConfig { n_examples, seed, ..Default::default() };
    let corpus = SyntheticCorpus::generate(&cfg);
    let tok = Tokenizer::from_texts(
        corpus.iter().map(|e| format!("{} {}", e.prompt, e.completion)),
        vocab_cap,
    );
    let exs = tokenize_corpus(&corpus, &tok, max_seq);
    (tok, exs)
}

/// Make batches for a given executable spec + packing choice.
pub fn make_batches(
    manifest: &Manifest,
    exe_name: &str,
    examples: &[TokenizedExample],
    packed: bool,
) -> Result<Vec<Batch>> {
    let spec = manifest.get(exe_name)?;
    let (b, s) = (spec.batch, spec.seq);
    let batches = if packed {
        packed_batches(examples, b, s)
    } else {
        padded_batches(examples, b, s)
    };
    if batches.is_empty() {
        return Err(anyhow!(
            "no complete batches for {exe_name} (B={b}, S={s}, {} examples)",
            examples.len()
        ));
    }
    Ok(batches)
}

/// Run one training configuration end to end, returning the summary row.
pub fn run_variant(backend: &Rc<dyn Backend>, cfg: &RunConfig) -> Result<TrainSummary> {
    let spec = backend.manifest().get(&cfg.executable)?.clone();
    // vocab cap = the model's vocab so token ids stay in range
    let vocab = spec.model_config.vocab.max(64);
    let (_tok, exs) = build_corpus(cfg.corpus_examples, cfg.seed, vocab, cfg.max_seq);
    let batches = make_batches(backend.manifest(), &cfg.executable, &exs, cfg.packed)?;

    let schedule = match cfg.lr_schedule.as_str() {
        "warmup_cosine" => LrSchedule::warmup_cosine(
            cfg.lr,
            cfg.lr_warmup_steps,
            cfg.steps,
            cfg.lora_plus_ratio,
        ),
        _ => LrSchedule::constant(cfg.lr, cfg.lora_plus_ratio),
    };

    // init state: families without an init executable reuse the family's
    // canonical init (same param set).
    let init_name = resolve_init(backend.manifest(), &cfg.executable, &cfg.init_name())?;
    let state = backend.init_state(&init_name, cfg.seed as i32)?;
    let mut trainer =
        Trainer::new(backend.clone(), &cfg.executable, state, schedule, cfg.warmup_steps)?;
    trainer.run(&batches, cfg.steps)
}

/// Find a usable init executable: the requested one, else the canonical
/// init for the same family and model/batch geometry.
pub fn resolve_init(manifest: &Manifest, train_name: &str, preferred: &str) -> Result<String> {
    if manifest.get(preferred).is_ok() {
        return Ok(preferred.to_string());
    }
    let train = manifest.get(train_name)?;
    for e in &manifest.executables {
        if e.kind == "init"
            && e.family == train.family
            && e.n_trainable == train.n_trainable
            && e.n_frozen == train.n_frozen
            // same tensor count is not enough — shapes must match too
            && e.param_count == train.param_count
        {
            return Ok(e.name.clone());
        }
    }
    Err(anyhow!("no init executable for {train_name}"))
}

/// Table 4 ablation ladder: run each rung, return report rows.
pub fn ablation_ladder(backend: &Rc<dyn Backend>, steps: u64) -> Result<Vec<Row>> {
    let rungs: &[(&str, &str, bool)] = &[
        ("Baseline (eager, padded)", "train_step_ablate_naive", false),
        ("+ FlashAttention", "train_step_ablate_flash", false),
        ("+ whole-graph compile", "train_step_ablate_compiled", false),
        ("+ fused kernels & CCE", "train_step_ablate_liger", false),
        ("+ sequence packing", "train_step_ablate_liger", true),
        ("+ fused optimizer", "train_step_chronicals", true),
    ];
    let mut rows = Vec::new();
    for (label, exe, packed) in rungs {
        let cfg = RunConfig {
            executable: exe.to_string(),
            steps,
            packed: *packed,
            warmup_steps: 2,
            ..RunConfig::default()
        };
        let s = run_variant(backend, &cfg)?;
        let spec = backend.manifest().get(exe)?;
        rows.push(Row::from_summary(label, "full", spec.batch, &s));
    }
    Ok(rows)
}

/// Table 2: full fine-tuning, naive ("Unsloth-correct"-shaped baseline) vs
/// chronicals, plus the broken "fast mode" row (Fig. 10).
pub fn full_ft_comparison(backend: &Rc<dyn Backend>, steps: u64) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for (label, exe, packed) in [
        ("Baseline (naive, verified)", "train_step_ablate_naive", false),
        ("Chronicals (verified)", "train_step_chronicals", true),
    ] {
        let cfg = RunConfig {
            executable: exe.to_string(),
            steps,
            packed,
            warmup_steps: 2,
            ..RunConfig::default()
        };
        let s = run_variant(backend, &cfg)?;
        let spec = backend.manifest().get(exe)?;
        rows.push(Row::from_summary(label, "full", spec.batch, &s));
    }
    Ok(rows)
}

/// Table 3: LoRA naive vs Chronicals LoRA vs LoRA+ (λ=16) vs broken mode.
pub fn lora_comparison(backend: &Rc<dyn Backend>, steps: u64) -> Result<Vec<Row>> {
    let runs: &[(&str, &str, bool, f64)] = &[
        ("LoRA naive (Unsloth-shaped)", "train_step_lora_naive", false, 1.0),
        ("Chronicals LoRA", "train_step_lora", true, 1.0),
        ("Chronicals LoRA+ (λ=16)", "train_step_lora", true, 16.0),
        ("'Fast mode' (BROKEN)", "train_step_lora_broken", true, 1.0),
    ];
    let mut rows = Vec::new();
    for (label, exe, packed, ratio) in runs {
        let cfg = RunConfig {
            executable: exe.to_string(),
            steps,
            packed: *packed,
            lora_plus_ratio: *ratio,
            lr: 1e-3,
            warmup_steps: 2,
            ..RunConfig::default()
        };
        let s = run_variant(backend, &cfg)?;
        let spec = backend.manifest().get(exe)?;
        rows.push(Row::from_summary(label, "lora", spec.batch, &s));
    }
    Ok(rows)
}

/// Table 5: fused-vs-naive kernel pairs. Supported on `cpu-fast` (its
/// fused/tiled kernels vs the reference scalar implementations on
/// identical inputs) and on PJRT (compiled kernel artifacts). The CPU
/// reference backend has no fused variants and reports a clean error.
pub fn kernel_microbench(backend: &dyn Backend, reps: usize) -> Result<Vec<(String, f64, f64)>> {
    let pairs = [
        ("RMSNorm", "kernel_rmsnorm_fused", "kernel_rmsnorm_naive"),
        ("SwiGLU", "kernel_swiglu_fused", "kernel_swiglu_naive"),
        ("QK-RoPE", "kernel_rope_fused", "kernel_rope_naive"),
        ("Attention", "kernel_attention_flash", "kernel_attention_naive"),
        ("Cross-Entropy", "kernel_cross_entropy_fused", "kernel_cross_entropy_naive"),
        ("AdamW", "kernel_adamw_fused", "kernel_adamw_naive"),
        ("LoRA Linear", "kernel_lora_linear_fused", "kernel_lora_linear_naive"),
    ];
    let mut out = Vec::new();
    for (label, fused, naive) in pairs {
        let tf = backend.bench_kernel(fused, reps, 2)?;
        let tn = backend.bench_kernel(naive, reps, 2)?;
        out.push((label.to_string(), tf, tn));
    }
    Ok(out)
}

/// Fig. 18 packing analysis over the synthetic corpus.
pub fn packing_report(capacity: usize, n_examples: usize) -> String {
    use crate::packing::*;
    let (_tok, exs) = build_corpus(n_examples, 42, 8192, capacity * 2);
    let lengths: Vec<usize> = exs.iter().map(|e| e.len()).collect();
    let algos: Vec<(&str, Packing)> = vec![
        ("No packing (padded)", no_packing(&lengths, capacity)),
        ("Next-Fit", next_fit(&lengths, capacity)),
        ("First-Fit Decreasing", first_fit_decreasing(&lengths, capacity)),
        ("Best-Fit Decreasing", best_fit_decreasing(&lengths, capacity)),
    ];
    let lb = Packing::opt_lower_bound(&lengths, capacity);
    let mut out = String::new();
    out.push_str(&format!(
        "## Packing (Fig. 18) — {} sequences, capacity {}, OPT ≥ {}\n",
        lengths.len(),
        capacity,
        lb
    ));
    out.push_str(&format!(
        "| {:<24} | {:>7} | {:>10} | {:>8} |\n|{}|\n",
        "Algorithm", "Bins", "Efficiency", "vs OPT",
        "-".repeat(60)
    ));
    for (name, p) in &algos {
        out.push_str(&format!(
            "| {:<24} | {:>7} | {:>9.1}% | {:>7.3}x |\n",
            name,
            p.n_bins(),
            p.efficiency() * 100.0,
            p.n_bins() as f64 / lb as f64
        ));
    }
    out
}

/// Render the full `bench --summary` report.
pub fn summary_report(backend: &Rc<dyn Backend>, steps: u64) -> Result<String> {
    let mut out = String::new();
    let full = full_ft_comparison(backend, steps)?;
    out.push_str(&report::throughput_table(
        "Full fine-tuning (paper Table 2)",
        &full,
        "Baseline (naive, verified)",
    ));
    out.push('\n');
    let lora = lora_comparison(backend, steps)?;
    out.push_str(&report::throughput_table(
        "LoRA r=32 (paper Table 3)",
        &lora,
        "LoRA naive (Unsloth-shaped)",
    ));
    out.push('\n');
    let ladder = ablation_ladder(backend, steps)?;
    out.push_str(&report::ablation_table(&ladder));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;

    #[test]
    fn resolve_init_falls_back_to_family_canonical() {
        let be = CpuBackend::new();
        // the ablation aliases have no init of their own; the canonical
        // full-family init must be found by geometry match
        let init = resolve_init(
            be.manifest(),
            "train_step_ablate_naive",
            "init_ablate_naive",
        )
        .unwrap();
        assert_eq!(init, "init_chronicals");
        // a broken lora variant resolves to the lora init
        let init =
            resolve_init(be.manifest(), "train_step_lora_broken", "init_lora_broken").unwrap();
        assert_eq!(init, "init_lora");
    }

    #[test]
    fn kernel_microbench_errors_cleanly_on_cpu() {
        let be = CpuBackend::new();
        let err = kernel_microbench(&be, 1).unwrap_err();
        assert!(err.to_string().contains("not supported"), "{err}");
    }

    #[test]
    fn kernel_microbench_runs_on_cpu_fast() {
        let be = crate::backend::cpu_fast::FastCpuBackend::with_threads(1);
        let rows = kernel_microbench(&be, 1).unwrap();
        assert_eq!(rows.len(), 7, "all Table-5 kernel pairs must time");
        for (name, fused, naive) in rows {
            assert!(fused > 0.0 && naive > 0.0, "{name}: {fused} vs {naive}");
        }
    }
}
