//! `chronicals` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   train    — run a training configuration (preset, config file or flags)
//!   bench    — regenerate the paper's tables (2/3/4/5) from live runs
//!   pack     — packing analysis (Fig. 18)
//!   inspect  — manifest / analytic memory model (Table 10, §S15)
//!   verify   — the Unsloth-bug demonstration (Fig. 10/22)
//!   serve    — multi-tenant fine-tuning service (fused LoRA rounds,
//!              DESIGN.md §11)
//!
//! Every subcommand takes `--backend cpu|cpu-fast|pjrt` (default `cpu`:
//! the hermetic pure-Rust reference backend; `cpu-fast` is the threaded
//! fused-kernel backend, `--threads N` / `CHRONICALS_THREADS` control its
//! parallelism; `pjrt` executes AOT artifacts and needs a `--features
//! pjrt` build plus `make artifacts`).
//!
//! Arg parsing is hand-rolled (offline build: no clap).

use anyhow::{anyhow, bail, Result};
use chronicals::backend::cpu::CpuBackend;
use chronicals::backend::cpu_fast::FastCpuBackend;
use chronicals::backend::{create_backend, Backend};
use chronicals::config::{self, RunConfig};
use chronicals::coordinator::TrainSummary;
use chronicals::harness;
use chronicals::metrics::{MemoryModel, Precision};
use chronicals::quant::{BaseQuant, OptimStates};
use chronicals::report;
use chronicals::serve::{FuseMode, JobSpec, ServeConfig, ServeEngine};
use chronicals::session::{
    BackendSpec, DataSource, LossMode, PackingStrategy, RunReport, Schedule, SessionBuilder,
    SessionSpec, Task,
};
use chronicals::util::commas;
use chronicals::util::json::Json;
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    flags: Vec<(String, String)>,
    #[allow(dead_code)] // kept for future positional subcommand args
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.push((k.to_string(), v.to_string()));
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.push((name.to_string(), argv[i + 1].clone()));
                    i += 1;
                } else {
                    flags.push((name.to_string(), "true".to_string()));
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { flags, positional }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    fn has(&self, name: &str) -> bool {
        self.get(name).is_some()
    }

    fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "bench" => cmd_bench(&args),
        "pack" => cmd_pack(&args),
        "inspect" => cmd_inspect(&args),
        "verify" => cmd_verify(&args),
        "serve" => cmd_serve(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `chronicals help`)"),
    }
}

fn print_help() {
    println!(
        "chronicals {} — high-performance LLM fine-tuning (paper reproduction)

USAGE: chronicals <command> [--flags]

COMMANDS
  train    --task <full-ft|lora|lora-plus|ablate-naive|ablate-flash|
                   ablate-compiled|ablate-liger|lora-naive|lora-broken>
           [--packing padded|bfd|ffd|next-fit] [--schedule constant|
           warmup-cosine] [--lr-warmup N] [--lora-rank N]
           [--lora-plus-ratio X] [--steps N] [--lr X] [--seed N]
           [--data-file FILE.jsonl[.gz]] [--tokenizer FILE.vocab]
           [--shuffle-seed N] [--epochs N] [--eval-fraction F]
           [--loss-mode response-only|full]
           [--backend cpu|cpu-fast|pjrt] [--threads N] [--workers N]
           [--optim-states fp32|int8] [--base-quant none|int8|fp8]
           [--ckpt-segments N] [--artifacts DIR]
           data: --data-file streams a JSONL instruction corpus
           ({{\"prompt\",\"completion\"}}, {{\"text\"}} or chat
           {{\"messages\":[{{\"role\",\"content\"}},..]}} per line; .jsonl.gz is
           inflated on the fly) through the byte-level mini-BPE tokenizer;
           --tokenizer loads/persists its vocab file; --shuffle-seed
           permutes the packing plan per epoch; --epochs N runs N data
           passes instead of cycling to --steps; --eval-fraction F holds
           out a seeded F of the examples (disjoint from train, stable
           under shuffling) and reports periodic held-out eval loss;
           --loss-mode full supervises prompts/user turns too (default:
           response-only)
           legacy front-ends (lowered into the same typed session):
           --preset <full_ft|lora|lora_plus|e2e> | --config <file.toml> |
           --executable NAME [--packed true|false]
           --workers N shards each batch row-wise across N data-parallel
           backend replicas with a fixed-order gradient reduction tree;
           the loss/grad-norm/eval series are bitwise identical for every
           N (cpu | cpu-fast backends only)
           memory tiers (DESIGN.md §12, cpu | cpu-fast): --optim-states
           int8 holds AdamW m/v in Kahan-compensated int8 blocks (≥3.5x
           smaller); --base-quant int8|fp8 quantizes the frozen base of a
           LoRA-family task, dequantized per tile inside the kernels;
           --ckpt-segments N recomputes interior activations in backward
           (bitwise identical to N=0)
  bench    --summary | --ablation | --kernels | --lora | --full
           [--steps N] [--reps N] [--backend cpu|cpu-fast|pjrt]
           [--threads N] [--artifacts DIR]
           --check [--check-threshold F]  re-measure the headline rows and
           fail if any drops more than F (default 0.2 = 20%) below the
           committed BENCH_cpu.json (sections marked verified = false are
           skipped)
  pack     [--capacity N] [--examples N]
  inspect  --manifest | --memory [--backend ...] [--artifacts DIR]
  verify   [--steps N] [--backend ...] [--artifacts DIR]
           (the Unsloth-bug demo)
  serve    --spool DIR | --jobs LIST.toml [--out DIR] [--once]
           [--max-rounds N] [--steps-per-round N] [--fuse on|off|intra]
           [--base-seed N] [--poll-ms N] [--round-stats FILE]
           [--optim-states fp32|int8] [--backend cpu|cpu-fast]
           [--threads N]
           multi-tenant fine-tuning service (DESIGN.md §11): admits TOML
           job files (from a watched spool dir and/or a 'jobs = [...]'
           manifest), shares one read-only base across tenants, fuses
           compatible LoRA/LoRA+ jobs into round-robin scheduling rounds
           (bitwise identical to running each job serially; --fuse off is
           the serial reference path, --fuse intra additionally fuses each
           round's tenants into one concatenated base forward/backward per
           quantum step — still bitwise identical), and streams one
           deterministic <out>/<id>.report.json per job as it completes;
           malformed jobs become <out>/<stem>.reject.txt diagnostics
           instead of crashing the server; --once drains the queue and
           exits (CI mode); --round-stats FILE writes an opt-in timing
           sidecar (rounds, tenants, rows, per-phase ms) outside --out

BACKENDS
  cpu       (default) pure-Rust deterministic reference — the correctness
            oracle; no artifacts needed
  cpu-fast  threaded fused-kernel backend (flash attention + cut
            cross-entropy); --threads N or CHRONICALS_THREADS=N pins the
            worker count (default: all cores)
  pjrt      AOT HLO artifacts via PJRT — requires a `--features pjrt`
            build, vendored xla-rs bindings and `make artifacts`
",
        chronicals::version()
    );
}

/// Worker-thread request: `CHRONICALS_THREADS` env > `--threads` flag
/// > config value > 0 (backend autodetects). A malformed `--threads`
/// value is an error, not a silent fallback.
fn thread_request(args: &Args, cfg_threads: usize) -> Result<usize> {
    // validate the flag first so a malformed value errors even when the
    // env override ends up winning
    let flag: Option<usize> = match args.get("threads") {
        Some(v) => Some(v.parse().map_err(|_| {
            anyhow!("invalid --threads '{v}' (expected a non-negative integer)")
        })?),
        None => None,
    };
    if let Some(n) = config::env_threads() {
        return Ok(n);
    }
    match flag {
        // 0 = explicit autodetect request
        Some(n) if n > 0 => Ok(n),
        _ => Ok(cfg_threads),
    }
}

fn load_backend(args: &Args) -> Result<Arc<dyn Backend>> {
    create_backend(
        args.get("backend").unwrap_or("cpu"),
        args.get("artifacts").unwrap_or("artifacts"),
        thread_request(args, 0)?,
    )
}

fn cmd_train(args: &Args) -> Result<()> {
    // 1) legacy front-ends: preset / TOML config / string flags
    let mut cfg = if let Some(preset) = args.get("preset") {
        RunConfig::preset(preset).ok_or_else(|| anyhow!("unknown preset '{preset}'"))?
    } else if let Some(path) = args.get("config") {
        RunConfig::from_file(path)?
    } else {
        RunConfig::default()
    };
    if let Some(exe) = args.get("executable") {
        cfg.executable = exe.to_string();
    }
    if args.has("steps") {
        cfg.steps = args.u64_or("steps", cfg.steps);
    }
    if let Some(p) = args.get("packed") {
        cfg.packed = p == "true";
    }
    if let Some(lr) = args.get("lr") {
        cfg.lr = lr.parse()?;
    }
    if let Some(r) = args.get("lora-plus-ratio") {
        cfg.lora_plus_ratio = r.parse()?;
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = s.parse()?;
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = b.to_string();
    }
    if let Some(f) = args.get("data-file") {
        cfg.data_file = f.to_string();
    }
    if let Some(t) = args.get("tokenizer") {
        cfg.tokenizer_file = t.to_string();
    }
    if cfg.data_file.is_empty() && !cfg.tokenizer_file.is_empty() {
        bail!("--tokenizer requires --data-file (the synthetic corpus has its own tokenizer)");
    }
    if let Some(s) = args.get("shuffle-seed") {
        cfg.shuffle_seed = Some(
            s.parse()
                .map_err(|_| anyhow!("invalid --shuffle-seed '{s}' (expected an integer)"))?,
        );
    }
    if let Some(e) = args.get("epochs") {
        cfg.epochs = Some(
            e.parse()
                .map_err(|_| anyhow!("invalid --epochs '{e}' (expected a positive integer)"))?,
        );
    }
    if let Some(f) = args.get("eval-fraction") {
        cfg.eval_fraction = Some(
            f.parse()
                .map_err(|_| anyhow!("invalid --eval-fraction '{f}' (expected e.g. 0.2)"))?,
        );
    }
    if let Some(m) = args.get("loss-mode") {
        cfg.loss_mode = m.to_string();
    }
    if let Some(w) = args.get("workers") {
        cfg.workers = w
            .parse::<usize>()
            .ok()
            .filter(|&n| n > 0)
            .ok_or_else(|| anyhow!("invalid --workers '{w}' (expected a positive integer)"))?;
    }
    // one parser for --threads everywhere (env > flag > config file)
    cfg.threads = thread_request(args, cfg.threads)?;

    // 2) lower into the typed spec, then apply the typed flags on top
    let mut spec = SessionSpec::from_run_config(&cfg)?;
    if let Some(name) = args.get("task") {
        let rank = args
            .get("lora-rank")
            .map(|v| v.parse::<usize>().map_err(|_| anyhow!("invalid --lora-rank '{v}'")))
            .transpose()?;
        let ratio = args
            .get("lora-plus-ratio")
            .map(|v| v.parse::<f64>().map_err(|_| anyhow!("invalid --lora-plus-ratio '{v}'")))
            .transpose()?;
        spec.task = Task::parse(name, rank, ratio)?;
    }
    if let Some(name) = args.get("schedule") {
        spec.schedule = Schedule::parse(name, args.u64_or("lr-warmup", cfg.lr_warmup_steps))?;
    }
    if let Some(name) = args.get("packing") {
        spec.packing = PackingStrategy::parse(name)?;
    }
    // memory-tier flags land on the spec (after --task, so the
    // base-quant × task validation sees the task the run will use)
    if let Some(s) = args.get("optim-states") {
        spec.optim_states = OptimStates::parse(s)?;
    }
    if let Some(q) = args.get("base-quant") {
        spec.base_quant = match q {
            "none" => None,
            name => Some(BaseQuant::parse(name)?),
        };
    }
    if let Some(n) = args.get("ckpt-segments") {
        spec.ckpt_segments = n.parse().map_err(|_| {
            anyhow!("invalid --ckpt-segments '{n}' (expected a non-negative integer)")
        })?;
    }

    let mut session = spec.build()?;
    let run_length = match session.spec().epoch_policy.epochs {
        Some(n) => format!("{n} epochs"),
        None => format!("{} steps", session.spec().steps),
    };
    println!(
        "training {} ({}) on the {} backend for {run_length} (packing={}, lr={}, λ={}, data={}{})",
        session.resolved().train,
        session.spec().task,
        session.backend().name(),
        session.spec().packing.name(),
        session.spec().lr,
        session.resolved().lora_plus_ratio,
        session.spec().data.label(),
        match session.spec().epoch_policy.shuffle {
            Some(s) => format!(", shuffle seed {s}"),
            None => String::new(),
        },
    );
    if session.spec().workers > 0 {
        println!(
            "data-parallel: {} replica{}, row-sharded batches, fixed-order gradient \
             reduction tree (bits invariant to the worker count)",
            session.spec().workers,
            if session.spec().workers == 1 { "" } else { "s" },
        );
    }
    if !session.spec().memory_cfg().is_default() {
        println!(
            "memory tiers: optimizer states {}, base weights {}, checkpoint segments {}",
            session.spec().optim_states.name(),
            session.spec().base_quant.map(|q| q.name()).unwrap_or("dense-fp32"),
            session.spec().ckpt_segments,
        );
    }
    let t0 = std::time::Instant::now();
    let report = session.run()?;
    let s = &report.summary;
    println!(
        "done in {:.1}s: loss {:.4} -> {:.4} | {} tok/s | {:.1} ms/step ±{:.1} | {}",
        t0.elapsed().as_secs_f64(),
        s.first_loss,
        s.last_loss,
        commas(s.tokens_per_sec as u64),
        s.mean_step_ms,
        s.std_step_ms,
        s.verification.status()
    );
    if let Some(p) = &s.phases {
        println!(
            "phases: fwd {:.2} ms | bwd {:.2} ms | optim {:.2} ms | data {:.2} ms per step \
             (post-warmup means; data = wall-time residual)",
            p.fwd_ms, p.bwd_ms, p.optim_ms, p.data_ms
        );
    }
    print_data_accounting(&report);
    if !report.eval.is_empty() {
        let series: Vec<String> =
            report.eval.iter().map(|(step, loss)| format!("{step}:{loss:.4}")).collect();
        println!(
            "eval: {} held-out examples | loss [{}] | final {:.4}",
            report.eval_examples,
            series.join(" "),
            report.final_eval_loss.unwrap_or(f32::NAN)
        );
    }
    for f in &s.verification.failures {
        println!("  verification failure: {f}");
    }
    if s.verification.final_step_grad_dead {
        println!(
            "\nWARNING: the final step's gradient norm was 0.0 or NaN — this run ended\n\
             NOT training (paper §9). Its throughput numbers are not admissible; check\n\
             for frozen weights, a detached graph, or numeric blow-up."
        );
    }
    Ok(())
}

/// Surface what the data pipeline did with the corpus — nothing is ever
/// dropped without a trace.
fn print_data_accounting(report: &RunReport) {
    println!(
        "data: {} examples -> {} batches over {} epoch{} ({} staged{})",
        report.examples,
        report.batches_planned,
        report.epochs,
        if report.epochs == 1 { "" } else { "s" },
        report.batches_staged,
        if report.tail_padded { ", partial tail padded" } else { "" }
    );
    println!(
        "  packing: {:.1}% of [B, S] slots hold real tokens; {:.1}% of the padded \
         baseline's waste recovered",
        report.packed_density * 100.0,
        report.padding_recovery * 100.0
    );
    if report.malformed_skipped > 0 {
        println!(
            "  warning: {} malformed records skipped (invalid JSON or schema):",
            report.malformed_skipped
        );
        for n in &report.source_notes {
            println!("    {n}");
        }
    }
    if report.truncated > 0 {
        println!(
            "  note: {} records truncated to the source's max_seq token cap",
            report.truncated
        );
    }
    if report.oversized_dropped > 0 {
        println!(
            "  warning: {} examples exceed the row capacity and were skipped \
             by the packing plan (raise max_seq truncation or use --packing padded)",
            report.oversized_dropped
        );
    }
}

fn cmd_bench(args: &Args) -> Result<()> {
    if args.has("check") {
        return cmd_bench_check(args);
    }
    let backend = load_backend(args)?;
    let steps = args.u64_or("steps", 12);
    let reps = args.u64_or("reps", 20) as usize;
    let mut any = false;
    if args.has("summary") {
        println!("{}", harness::summary_report(&backend, steps)?);
        any = true;
    }
    if args.has("full") {
        let rows = harness::full_ft_comparison(&backend, steps)?;
        println!(
            "{}",
            report::throughput_table(
                "Full fine-tuning (paper Table 2)",
                &rows,
                "Baseline (naive, verified)"
            )
        );
        any = true;
    }
    if args.has("lora") {
        let rows = harness::lora_comparison(&backend, steps)?;
        println!(
            "{}",
            report::throughput_table(
                "LoRA r=32 (paper Table 3)",
                &rows,
                "LoRA naive (Unsloth-shaped)"
            )
        );
        any = true;
    }
    if args.has("ablation") {
        let rows = harness::ablation_ladder(&backend, steps)?;
        println!("{}", report::ablation_table(&rows));
        any = true;
    }
    if args.has("kernels") {
        let rows = harness::kernel_microbench(backend.as_ref(), reps)?;
        println!("{}", report::kernel_table(&rows));
        any = true;
    }
    if !any {
        println!("nothing to do: pass --summary, --full, --lora, --ablation or --kernels");
    }
    Ok(())
}

/// The `bench_throughput` measurement geometry — `bench --check` must
/// re-measure under the same [B, S] the committed numbers were taken at.
const CHECK_BATCH: usize = 4;
const CHECK_SEQ: usize = 128;

/// One fresh measurement row for the regression gate, using the exact
/// session settings `benches/bench_throughput.rs` committed its numbers
/// under. A row that fails to run is reported and skipped — the check
/// then fails only if a *measured* number regressed.
fn check_row(backend: &Arc<dyn Backend>, task: Task, steps: u64) -> Option<TrainSummary> {
    let result = SessionBuilder::new()
        .task(task.clone())
        .steps(steps)
        .meter_warmup(2)
        .lr(5e-3)
        .packing(PackingStrategy::Bfd)
        .data(DataSource::synthetic(384, 42, 96))
        .on_backend(backend.clone())
        .build()
        .and_then(|mut session| session.run());
    match result {
        Ok(r) => Some(r.summary),
        Err(e) => {
            eprintln!("  row failed ({task} on {}): {e:#}", backend.name());
            None
        }
    }
}

/// One fresh serve-ladder rung for `bench --check`: `tenants` LoRA jobs
/// drained in `--once` mode under `mode` on the fast backend at the check
/// geometry. Tokens/sec uses the same slot definition the committed
/// `serve` section records: `tenants × steps × B × S` over wall-clock.
fn serve_check_row(mode: FuseMode, tenants: usize, steps: u64) -> Option<f64> {
    let out = std::env::temp_dir().join(format!(
        "chronicals_bench_check_serve_{}_{mode:?}_{tenants}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&out);
    let backend: Arc<dyn Backend> =
        Arc::new(FastCpuBackend::with_geometry(CHECK_BATCH, CHECK_SEQ));
    let cfg = ServeConfig {
        out_dir: out.clone(),
        fuse: mode,
        steps_per_round: 4,
        ..Default::default()
    };
    let res = (|| {
        let mut engine = ServeEngine::new(backend, cfg).ok()?;
        for i in 0..tenants {
            engine
                .admit_spec(JobSpec {
                    id: format!("tenant-{i}"),
                    task: Task::lora(),
                    steps,
                    lr: 5e-3,
                    seed: 7 + i as i64,
                    schedule: Schedule::Constant,
                    loss_mode: LossMode::default(),
                    data: DataSource::synthetic(40, 3 + i as u64, 48),
                })
                .ok()?;
        }
        let t0 = std::time::Instant::now();
        let summary = engine.run().ok()?;
        let secs = t0.elapsed().as_secs_f64();
        if summary.completed != tenants || secs <= 0.0 {
            return None;
        }
        Some((tenants as u64 * steps) as f64 * (CHECK_BATCH * CHECK_SEQ) as f64 / secs)
    })();
    let _ = std::fs::remove_dir_all(&out);
    if res.is_none() {
        eprintln!("  row failed (serve {mode:?} tenants={tenants})");
    }
    res
}

/// `bench --check`: re-measure the headline throughput rows and the
/// data-parallel worker ladder, then gate them against the committed
/// repo-root `BENCH_cpu.json` — a fresh number more than
/// `--check-threshold` (default 0.2 = 20%) below its committed value is
/// a regression and exits non-zero. Sections still marked
/// `verified = false` (seed placeholders) are skipped.
fn cmd_bench_check(args: &Args) -> Result<()> {
    let steps = args.u64_or("steps", 12);
    let threshold: f64 = match args.get("check-threshold") {
        Some(v) => {
            let t: f64 = v.parse().map_err(|_| {
                anyhow!("invalid --check-threshold '{v}' (expected a fraction, e.g. 0.2)")
            })?;
            if !(0.0..1.0).contains(&t) {
                bail!("--check-threshold must be in [0, 1) (got {t})");
            }
            t
        }
        None => 0.2,
    };
    let path = report::bench_json_path();
    let text = std::fs::read_to_string(&path).map_err(|e| {
        anyhow!("reading committed bench report {}: {e} (run `cargo bench` first)", path.display())
    })?;
    let committed =
        Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
    let threads = thread_request(args, 0)?;
    println!(
        "bench --check: {steps} steps per row, regression threshold {:.0}%, \
         committed report {}",
        threshold * 100.0,
        path.display()
    );

    let mut fresh: Vec<(String, f64)> = Vec::new();
    let reference: Arc<dyn Backend> = Arc::new(CpuBackend::with_geometry(CHECK_BATCH, CHECK_SEQ));
    let fast: Arc<dyn Backend> = Arc::new(FastCpuBackend::with_geometry(CHECK_BATCH, CHECK_SEQ));
    for (mode, task) in [("full_ft", Task::FullFinetune), ("lora", Task::lora())] {
        if let Some(s) = check_row(&reference, task.clone(), steps) {
            fresh.push((format!("throughput.{mode}.cpu_tokens_per_sec"), s.tokens_per_sec));
        }
        if let Some(s) = check_row(&fast, task, steps) {
            fresh.push((format!("throughput.{mode}.cpu_fast_tokens_per_sec"), s.tokens_per_sec));
        }
    }
    // the data-parallel worker ladder (replicas built from the spec, the
    // same settings bench_throughput's data_parallel section records)
    for workers in [1usize, 2, 4] {
        let result = SessionBuilder::new()
            .task(Task::FullFinetune)
            .steps(steps)
            .meter_warmup(2)
            .lr(5e-3)
            .packing(PackingStrategy::Bfd)
            .data(DataSource::synthetic(384, 42, 96))
            .backend(BackendSpec::CpuFast { threads })
            .workers(workers)
            .build()
            .and_then(|mut session| session.run());
        match result {
            Ok(r) => fresh.push((
                format!("data_parallel.workers_{workers}.tokens_per_sec"),
                r.summary.tokens_per_sec,
            )),
            Err(e) => eprintln!("  row failed (data-parallel workers={workers}): {e:#}"),
        }
    }
    // the serve fusion ladder — same slot-throughput definition the
    // committed `serve` section records; skipped while that section ships
    // verified = false, but the rows are produced so flipping the flag
    // arms the gate with no code change
    for tenants in [2usize, 4] {
        for (label, mode) in [
            ("serial", FuseMode::Off),
            ("round_fused", FuseMode::Round),
            ("intra_fused", FuseMode::Intra),
        ] {
            if let Some(tps) = serve_check_row(mode, tenants, steps) {
                fresh.push((
                    format!("serve.intra_step_fusion.{label}_{tenants}.tokens_per_sec"),
                    tps,
                ));
            }
        }
    }
    // the memory-tier ladder (DESIGN.md §12) — the same tiers bench_quant's
    // `memory_tiers` section records; skipped while that section ships
    // verified = false, but the rows are produced so flipping the flag
    // arms the gate with no code change
    for (label, optim, base, segs) in [
        ("legacy", OptimStates::Fp32, None, 0usize),
        ("int8_optim", OptimStates::Int8, None, 0),
        ("int8_base", OptimStates::Fp32, Some(BaseQuant::Int8), 0),
        ("all_tiers", OptimStates::Int8, Some(BaseQuant::Int8), 2),
    ] {
        let mut builder = SessionBuilder::new()
            .task(Task::lora())
            .steps(steps)
            .meter_warmup(2)
            .lr(2e-3)
            .packing(PackingStrategy::Bfd)
            .data(DataSource::synthetic(384, 42, 96))
            .backend(BackendSpec::CpuFast { threads })
            .optim_states(optim)
            .ckpt_segments(segs);
        if let Some(q) = base {
            builder = builder.base_quant(q);
        }
        match builder.build().and_then(|mut session| session.run()) {
            Ok(r) => fresh.push((
                format!("memory_tiers.rows.{label}.tokens_per_sec"),
                r.summary.tokens_per_sec,
            )),
            Err(e) => eprintln!("  row failed (memory tier {label}): {e:#}"),
        }
    }

    let out = report::check_bench_metrics(&committed, &fresh, threshold);
    for l in &out.checked {
        println!("  ok   {l}");
    }
    for l in &out.skipped {
        println!("  skip {l}");
    }
    for l in &out.regressions {
        println!("  FAIL {l}");
    }
    println!(
        "bench --check: {} compared, {} skipped, {} regressed",
        out.checked.len(),
        out.skipped.len(),
        out.regressions.len()
    );
    if !out.passed() {
        bail!(
            "bench --check failed: {} metric(s) regressed more than {:.0}% below \
             the committed report",
            out.regressions.len(),
            threshold * 100.0
        );
    }
    Ok(())
}

fn cmd_pack(args: &Args) -> Result<()> {
    let capacity = args.u64_or("capacity", 512) as usize;
    let examples = args.u64_or("examples", 4096) as usize;
    println!("{}", harness::packing_report(capacity, examples));
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    if args.has("manifest") {
        let backend = load_backend(args)?;
        let manifest = backend.manifest();
        println!(
            "manifest: backend={} profile={} executables={}",
            backend.name(),
            manifest.profile,
            manifest.executables.len()
        );
        for e in &manifest.executables {
            println!(
                "  {:<34} kind={:<6} B={} S={} params={} trainable={}",
                e.name,
                e.kind,
                e.batch,
                e.seq,
                commas(e.param_count),
                commas(e.trainable_param_count)
            );
        }
        return Ok(());
    }
    if args.has("memory") {
        // paper-scale model: Qwen2.5-0.5B on A100 (Table 10 / §S15)
        let m = MemoryModel {
            params: 494_000_000,
            n_layers: 24,
            d_model: 896,
            n_heads: 14,
            vocab: 151_936,
            batch: 8,
            seq: 2048,
            weight_prec: Precision::Bf16,
            grad_prec: Precision::Bf16,
            optimizer_bytes_per_param: 8,
        };
        println!("{}", report::memory_table("naive training (paper §1/§S15)", &m.naive()));
        let k = m.optimal_checkpoint_k();
        println!(
            "{}",
            report::memory_table(
                &format!("Chronicals (CCE chunk 4096, checkpoint k*={k})"),
                &m.chronicals(4096, Some(k)),
            )
        );
        println!(
            "CCE logit reduction: {}x (paper Thm. 3: V/C = 151936/4096 ≈ 37)",
            m.naive().logits / m.chronicals(4096, None).logits.max(1)
        );
        return Ok(());
    }
    bail!("pass --manifest or --memory")
}

fn cmd_serve(args: &Args) -> Result<()> {
    let spool = args.get("spool").map(std::path::PathBuf::from);
    let jobs_manifest = args.get("jobs").map(std::path::PathBuf::from);
    if spool.is_none() && jobs_manifest.is_none() {
        bail!("serve needs a job source: --spool DIR and/or --jobs LIST.toml");
    }
    let max_rounds = args
        .get("max-rounds")
        .map(|v| {
            v.parse::<u64>()
                .map_err(|_| anyhow!("invalid --max-rounds '{v}' (expected a positive integer)"))
        })
        .transpose()?;
    let fuse = match args.get("fuse") {
        None => FuseMode::Round,
        Some("on") | Some("true") => FuseMode::Round,
        Some("off") | Some("false") => FuseMode::Off,
        Some("intra") => FuseMode::Intra,
        Some(other) => bail!("invalid --fuse '{other}' (expected on | off | intra)"),
    };
    let base_seed: i32 = match args.get("base-seed") {
        Some(v) => v
            .parse()
            .map_err(|_| anyhow!("invalid --base-seed '{v}' (expected an integer)"))?,
        None => 0,
    };
    let optim_states = match args.get("optim-states") {
        None => chronicals::quant::OptimStates::Fp32,
        Some(name) => chronicals::quant::OptimStates::parse(name)?,
    };
    let cfg = ServeConfig {
        spool,
        jobs_manifest,
        out_dir: std::path::PathBuf::from(args.get("out").unwrap_or("serve-out")),
        once: args.has("once"),
        max_rounds,
        steps_per_round: args.u64_or("steps-per-round", 4),
        fuse,
        base_seed,
        poll_ms: args.u64_or("poll-ms", 500),
        round_stats: args.get("round-stats").map(std::path::PathBuf::from),
        optim_states,
    };
    let backend = load_backend(args)?;
    println!(
        "serve: {} backend, fusion {}, {} steps/round, base seed {}{}",
        backend.name(),
        match cfg.fuse {
            FuseMode::Off => "off",
            FuseMode::Round => "on",
            FuseMode::Intra => "intra",
        },
        cfg.steps_per_round,
        cfg.base_seed,
        if cfg.once { ", --once (drain and exit)" } else { ", watching for jobs" },
    );
    let t0 = std::time::Instant::now();
    let mut engine = ServeEngine::new(backend, cfg)?;
    let s = engine.run()?;
    println!(
        "serve: {} admitted, {} rejected, {} completed over {} rounds ({} fused, {} intra-fused) in {:.1}s",
        s.admitted,
        s.rejected,
        s.completed,
        s.rounds,
        s.fused_rounds,
        s.intra_fused_rounds,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let backend = load_backend(args)?;
    let steps = args.u64_or("steps", 8);
    println!("reproducing the paper's Unsloth-bug finding (Fig. 10/22)\n");
    let runs = [
        ("correct LoRA config", Task::lora(), false),
        ("'fast mode' config", Task::LoraBroken, true),
    ];
    for (label, task, expect_dead) in runs {
        let mut session = SessionBuilder::new()
            .task(task)
            .steps(steps)
            .lr(1e-3)
            .meter_warmup(1)
            .on_backend(backend.clone())
            .build()?;
        let s = session.run()?.summary;
        println!(
            "{label}: {} tok/s | loss {:.4} -> {:.4} | grad_norm in [{:.2e}, {:.2e}] | {}",
            commas(s.tokens_per_sec as u64),
            s.first_loss,
            s.last_loss,
            s.verification.min_grad_norm,
            s.verification.max_grad_norm,
            s.verification.status()
        );
        for f in &s.verification.failures {
            println!("    -> {f}");
        }
        // the §9 guard must fire on the frozen-weights config and stay
        // clear on the healthy one — `verify` is itself verified
        if s.verification.final_step_grad_dead != expect_dead {
            bail!(
                "§9 final-step guard mismatch for {label}: expected dead={expect_dead}, \
                 got dead={} (grad_norm range [{:.2e}, {:.2e}])",
                s.verification.final_step_grad_dead,
                s.verification.min_grad_norm,
                s.verification.max_grad_norm
            );
        }
    }
    println!(
        "\nThe broken config reports HIGHER throughput (the backward pass is\n\
         dead-code-eliminated) while training nothing — exactly the paper's\n\
         46k-tokens/sec-with-zero-gradients finding. Always verify gradient flow.\n\
         §9 final-step guard: fired on the broken config, clear on the healthy one."
    );
    Ok(())
}
