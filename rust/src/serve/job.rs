//! Job specs for `chronicals serve` (DESIGN.md §11): the TOML job-file
//! format, admission validation with real error messages, and the pure
//! round-grouping rules that decide which tenants may share a fused
//! scheduling round.
//!
//! A job file is a flat TOML document (an optional `[job]` section header
//! is accepted and ignored) describing one tenant's fine-tuning session:
//!
//! ```toml
//! id = "tenant-a"        # required; names the report file
//! task = "lora"          # full-ft | lora | lora-plus | ... (default lora)
//! steps = 8              # per-job step budget (default 8)
//! lr = 0.005             # default 5e-3
//! seed = 7               # tenant seed: adapter init + default data seed
//! examples = 64          # synthetic-corpus size (default data source)
//! ```
//!
//! Every key is validated on admission — unknown keys, duplicate keys, a
//! missing or malformed `id`, non-positive `steps`/`lr` are all rejected
//! with messages that name the offending key, so a malformed job becomes a
//! diagnostic file instead of a crashed server.

use crate::manifest::ExecutableSpec;
use crate::session::{DataSource, LossMode, Schedule, Task};
use crate::util::toml::{TomlDoc, TomlValue};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One admitted tenant job: a validated, typed fine-tuning request. The
/// fields mirror the session vocabulary ([`Task`], [`Schedule`],
/// [`DataSource`]) so admission is exactly the spec → session lowering.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Unique job id (`[A-Za-z0-9_-]+`); names the per-job report file.
    pub id: String,
    /// What to train (FullFinetune is accepted but never fused).
    pub task: Task,
    /// Per-job step budget: the job completes after exactly this many
    /// optimizer steps, spread across scheduling rounds.
    pub steps: u64,
    /// Base learning rate (LoRA+ jobs derive `lr_b = λ·lr` from the task).
    pub lr: f64,
    /// Tenant seed: drives the adapter init and, unless `data_seed`
    /// overrides it, the data source. The *base* weights come from the
    /// server-wide base seed, never from here.
    pub seed: i64,
    /// Learning-rate schedule over the job's own step budget.
    pub schedule: Schedule,
    /// Which token positions are supervised (file-backed sources).
    pub loss_mode: LossMode,
    /// Where this tenant's training data comes from.
    pub data: DataSource,
}

/// Every key a job file may set. Kept in one place so the unknown-key
/// diagnostic can enumerate the whole vocabulary.
const ALLOWED_KEYS: &[&str] = &[
    "id",
    "task",
    "lora_rank",
    "lora_plus_ratio",
    "steps",
    "lr",
    "seed",
    "schedule",
    "warmup",
    "loss_mode",
    "data",
    "data_file",
    "examples",
    "data_seed",
    "max_seq",
];

impl JobSpec {
    /// Parse and validate a job file's text. `base_dir` anchors relative
    /// `data_file` paths (the job file's own directory when loading from
    /// disk). Every admission error names the offending key or value.
    pub fn parse(text: &str, base_dir: &Path) -> Result<JobSpec> {
        let doc = TomlDoc::parse(text).context("parsing job TOML")?;
        // normalize the optional [job] section away, then reject unknown
        // and duplicate keys before reading anything
        let mut entries: Vec<(String, TomlValue)> = Vec::new();
        for (k, v) in doc.entries {
            let bare = k.strip_prefix("job.").unwrap_or(&k).to_string();
            if !ALLOWED_KEYS.contains(&bare.as_str()) {
                bail!("unknown key '{k}' in job file (allowed: {})", ALLOWED_KEYS.join(", "));
            }
            if entries.iter().any(|(e, _)| *e == bare) {
                bail!("duplicate key '{bare}' in job file");
            }
            entries.push((bare, v));
        }
        let get = |key: &str| entries.iter().find(|(k, _)| k == key).map(|(_, v)| v);
        let get_str = |key: &str| -> Result<Option<&str>> {
            match get(key) {
                None => Ok(None),
                Some(v) => Ok(Some(v.as_str().with_context(|| {
                    format!("key '{key}' must be a quoted string")
                })?)),
            }
        };
        let get_int = |key: &str| -> Result<Option<i64>> {
            match get(key) {
                None => Ok(None),
                Some(v) => {
                    Ok(Some(v.as_i64().with_context(|| format!("key '{key}' must be an integer"))?))
                }
            }
        };

        let id = match get_str("id")? {
            Some(s) => s.to_string(),
            None => bail!("job file is missing the required key 'id'"),
        };
        if id.is_empty()
            || !id.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            bail!("invalid job id '{id}': use [A-Za-z0-9_-]+ (the id names the report file)");
        }

        let rank = match get_int("lora_rank")? {
            Some(r) if r > 0 => Some(r as usize),
            Some(r) => bail!("key 'lora_rank' must be a positive integer (got {r})"),
            None => None,
        };
        let ratio = match get("lora_plus_ratio") {
            Some(v) => Some(
                v.as_f64()
                    .with_context(|| "key 'lora_plus_ratio' must be a number".to_string())?,
            ),
            None => None,
        };
        let task = Task::parse(get_str("task")?.unwrap_or("lora"), rank, ratio)
            .context("key 'task'")?;

        let steps = get_int("steps")?.unwrap_or(8);
        if steps <= 0 {
            bail!("key 'steps' must be a positive step budget (got {steps})");
        }
        let lr = match get("lr") {
            Some(v) => v.as_f64().with_context(|| "key 'lr' must be a number".to_string())?,
            None => 5e-3,
        };
        if !(lr.is_finite() && lr > 0.0) {
            bail!("key 'lr' must be a positive finite learning rate (got {lr})");
        }
        let seed = get_int("seed")?.unwrap_or(0);
        let warmup = match get_int("warmup")? {
            Some(w) if w >= 0 => w as u64,
            Some(w) => bail!("key 'warmup' must be non-negative (got {w})"),
            None => 0,
        };
        let schedule = Schedule::parse(get_str("schedule")?.unwrap_or("constant"), warmup)
            .context("key 'schedule'")?;
        let loss_mode = LossMode::parse(get_str("loss_mode")?.unwrap_or("response-only"))
            .context("key 'loss_mode'")?;

        let max_seq = match get_int("max_seq")? {
            Some(m) if m > 0 => m as usize,
            Some(m) => bail!("key 'max_seq' must be a positive token cap (got {m})"),
            None => 64,
        };
        let data_seed = match get_int("data_seed")? {
            Some(s) => s as u64,
            None => seed as u64,
        };
        let kind = get_str("data")?.unwrap_or("synthetic");
        let data_file = get_str("data_file")?;
        let data = match kind {
            "synthetic" => {
                if let Some(f) = data_file {
                    bail!(
                        "key 'data_file' ('{f}') requires data = \"jsonl\" or data = \"chat\" \
                         (the default data = \"synthetic\" generates its own corpus)"
                    );
                }
                let examples = match get_int("examples")? {
                    Some(n) if n > 0 => n as usize,
                    Some(n) => bail!("key 'examples' must be a positive count (got {n})"),
                    None => 64,
                };
                DataSource::synthetic(examples, data_seed, max_seq)
            }
            "jsonl" | "chat" => {
                if get("examples").is_some() {
                    bail!("key 'examples' only applies to data = \"synthetic\"");
                }
                let f = match data_file {
                    Some(f) => f,
                    None => bail!("data = \"{kind}\" requires a 'data_file' path"),
                };
                let path = base_dir.join(f).to_string_lossy().into_owned();
                if kind == "jsonl" {
                    DataSource::jsonl(path, data_seed, max_seq)
                } else {
                    DataSource::chat(path, data_seed, max_seq)
                }
            }
            other => bail!("unknown data kind '{other}' (expected synthetic | jsonl | chat)"),
        };

        Ok(JobSpec { id, task, steps: steps as u64, lr, seed, schedule, loss_mode, data })
    }

    /// Load and validate a job file from disk.
    pub fn from_file(path: &Path) -> Result<JobSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading job file {}", path.display()))?;
        let base = path.parent().unwrap_or(Path::new("."));
        JobSpec::parse(&text, base)
    }
}

/// What must match for two jobs to share a fused scheduling round: the
/// task must be fusable at all (LoRA/LoRA+ on a backend with per-tenant
/// adapter support — FullFinetune and the ablation/broken variants never
/// fuse), and the jobs must train the same executable family at the same
/// batch geometry, model dimensions and LoRA shape so one workspace's
/// shared base serves every member. Jobs whose keys differ land in
/// different rounds — never silently co-batched.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FuseKey {
    /// Whether this job may share a round at all.
    pub fusable: bool,
    /// Executable family ("lora", "full", …).
    pub family: String,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub lora_rank: usize,
    pub lora_alpha: usize,
}

impl FuseKey {
    /// The fuse key of a task resolved to a concrete executable spec.
    /// `fuse_enabled` gates fusion globally (`--fuse off` and backends
    /// without adapter support force every job serial).
    pub fn for_job(task: &Task, spec: &ExecutableSpec, fuse_enabled: bool) -> FuseKey {
        let fusable =
            fuse_enabled && matches!(task, Task::Lora { .. } | Task::LoraPlus { .. });
        FuseKey {
            fusable,
            family: spec.family.clone(),
            batch: spec.batch,
            seq: spec.seq,
            vocab: spec.model_config.vocab,
            d_model: spec.model_config.d_model,
            n_layers: spec.model_config.n_layers,
            n_heads: spec.model_config.n_heads,
            n_kv_heads: spec.model_config.n_kv_heads,
            d_ff: spec.model_config.d_ff,
            lora_rank: spec.step_config.lora_rank,
            lora_alpha: spec.step_config.lora_alpha,
        }
    }
}

/// Group pending jobs into scheduling rounds, deterministically.
///
/// Jobs are walked in admission order. A fusable job joins the round
/// opened by the first earlier job with an identical [`FuseKey`]; a
/// non-fusable job always gets a singleton round. Rounds are returned in
/// the order they were opened, each holding indices into `keys` in
/// admission order — so the schedule is a pure function of the pending
/// set, independent of timing.
pub fn group_rounds(keys: &[FuseKey]) -> Vec<Vec<usize>> {
    let mut rounds: Vec<Vec<usize>> = Vec::new();
    // (key, round index) for rounds that accept more members
    let mut open: Vec<(&FuseKey, usize)> = Vec::new();
    for (i, k) in keys.iter().enumerate() {
        if !k.fusable {
            rounds.push(vec![i]);
            continue;
        }
        match open.iter().find(|(ok, _)| *ok == k) {
            Some(&(_, r)) => rounds[r].push(i),
            None => {
                rounds.push(vec![i]);
                open.push((k, rounds.len() - 1));
            }
        }
    }
    rounds
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<JobSpec> {
        JobSpec::parse(text, Path::new("."))
    }

    #[test]
    fn minimal_job_gets_documented_defaults() {
        let j = parse("id = \"t1\"").unwrap();
        assert_eq!(j.id, "t1");
        assert_eq!(j.task, Task::Lora { rank: None });
        assert_eq!(j.steps, 8);
        assert_eq!(j.lr, 5e-3);
        assert_eq!(j.seed, 0);
        assert_eq!(j.schedule, Schedule::Constant);
        assert_eq!(j.data, DataSource::synthetic(64, 0, 64));
    }

    #[test]
    fn full_vocabulary_parses() {
        let j = parse(
            "[job]\n\
             id = \"t2\"\n\
             task = \"lora-plus\"\n\
             lora_plus_ratio = 8.0\n\
             steps = 12\n\
             lr = 0.001\n\
             seed = 7\n\
             schedule = \"warmup-cosine\"\n\
             warmup = 2\n\
             examples = 32\n\
             data_seed = 9\n\
             max_seq = 48\n",
        )
        .unwrap();
        assert_eq!(j.task, Task::LoraPlus { rank: None, ratio: 8.0 });
        assert_eq!(j.steps, 12);
        assert_eq!(j.seed, 7);
        assert_eq!(j.schedule, Schedule::WarmupCosine { warmup: 2 });
        assert_eq!(j.data, DataSource::synthetic(32, 9, 48));
    }

    /// Full error chain as text (`{:#}` renders contexts + root cause).
    fn perr(text: &str) -> String {
        format!("{:#}", parse(text).unwrap_err())
    }

    #[test]
    fn admission_errors_name_the_offending_key() {
        let err = perr("id = \"x\"\nspeed = 3\n");
        assert!(err.contains("unknown key 'speed'"), "{err}");
        let err = perr("task = \"lora\"");
        assert!(err.contains("missing the required key 'id'"), "{err}");
        let err = perr("id = \"bad id!\"");
        assert!(err.contains("invalid job id"), "{err}");
        let err = perr("id = \"x\"\nid = \"y\"\n");
        assert!(err.contains("duplicate key 'id'"), "{err}");
        let err = perr("id = \"x\"\nsteps = 0\n");
        assert!(err.contains("'steps'"), "{err}");
        let err = perr("id = \"x\"\nlr = -1.0\n");
        assert!(err.contains("'lr'"), "{err}");
        let err = perr("id = \"x\"\ntask = \"warp\"\n");
        assert!(err.contains("unknown task"), "{err}");
        let err = perr("id = \"x\"\ndata_file = \"c.jsonl\"\n");
        assert!(err.contains("data_file"), "{err}");
        let err = perr("id = \"x\"\ndata = \"chat\"\n");
        assert!(err.contains("requires a 'data_file'"), "{err}");
    }

    #[test]
    fn file_backed_data_paths_are_anchored_to_the_job_dir() {
        let j = JobSpec::parse(
            "id = \"t\"\ndata = \"chat\"\ndata_file = \"corpus.jsonl\"\n",
            Path::new("/spool"),
        )
        .unwrap();
        match &j.data {
            DataSource::Chat { file, .. } => assert!(file.ends_with("/spool/corpus.jsonl")),
            other => panic!("expected chat source, got {other:?}"),
        }
    }

    fn key(fusable: bool, seq: usize) -> FuseKey {
        FuseKey {
            fusable,
            family: "lora".into(),
            batch: 4,
            seq,
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 64,
            lora_rank: 4,
            lora_alpha: 8,
        }
    }

    #[test]
    fn compatible_jobs_share_a_round_in_admission_order() {
        let rounds = group_rounds(&[key(true, 64), key(true, 64), key(true, 64)]);
        assert_eq!(rounds, vec![vec![0, 1, 2]]);
    }

    #[test]
    fn geometry_mismatch_lands_in_different_rounds() {
        // same family, different seq: never silently co-batched
        let rounds = group_rounds(&[key(true, 64), key(true, 128), key(true, 64)]);
        assert_eq!(rounds, vec![vec![0, 2], vec![1]]);
    }

    #[test]
    fn non_fusable_jobs_always_get_singleton_rounds() {
        let rounds =
            group_rounds(&[key(true, 64), key(false, 64), key(false, 64), key(true, 64)]);
        assert_eq!(rounds, vec![vec![0, 3], vec![1], vec![2]]);
    }

    #[test]
    fn fuse_keys_come_from_the_resolved_executable_spec() {
        use crate::backend::cpu::CpuBackend;
        use crate::backend::Backend;
        use crate::session::resolve::resolve;
        let be = CpuBackend::new();
        let lora = resolve(be.manifest(), &Task::lora()).unwrap();
        let plus = resolve(be.manifest(), &Task::lora_plus(16.0)).unwrap();
        let full = resolve(be.manifest(), &Task::FullFinetune).unwrap();
        let k_lora = FuseKey::for_job(&Task::lora(), &lora.spec, true);
        let k_plus = FuseKey::for_job(&Task::lora_plus(16.0), &plus.spec, true);
        let k_full = FuseKey::for_job(&Task::FullFinetune, &full.spec, true);
        // LoRA and LoRA+ run the same executable → identical keys, fusable
        assert_eq!(k_lora, k_plus);
        assert!(k_lora.fusable);
        // FullFinetune is never fusable, even with fusion enabled
        assert!(!k_full.fusable);
        // --fuse off forces everything serial
        assert!(!FuseKey::for_job(&Task::lora(), &lora.spec, false).fusable);
    }
}
