//! `chronicals serve` — a deterministic multi-tenant fine-tuning service
//! (DESIGN.md §11).
//!
//! The engine admits [`JobSpec`]s — from TOML job files dropped into a
//! watched spool directory, an inline `jobs = [...]` manifest, or
//! programmatically via [`ServeEngine::admit_spec`] — validates each on
//! admission (malformed jobs become `<stem>.reject.txt` diagnostic files,
//! never a crashed server), groups compatible LoRA/LoRA+ tenants into
//! fused scheduling rounds, and streams one deterministic
//! `<id>.report.json` per job as it completes its step budget.
//!
//! ## The shared-base / per-adapter state split
//!
//! Each fuse group owns one workspace [`DeviceState`] initialized from the
//! server-wide base seed. Its frozen suffix *is* the shared base — loaded
//! once, read by every tenant, never written. Each tenant owns an
//! [`AdapterState`]: the trainable LoRA A/B tensors plus their AdamW
//! slots, seeded from the tenant's own seed. A fused round time-slices
//! tenants onto the workspace by swapping their adapters into the
//! trainable prefix (an O(1) pointer exchange), running the tenant's slice
//! of ordinary `train_step`s, and swapping back out.
//!
//! ## The fused-vs-serial determinism contract
//!
//! Because a swap moves tensors without touching their values, and the
//! base never changes, the fused path executes bit-for-bit the same
//! arithmetic as running each tenant alone on a dedicated state. `--fuse
//! off` takes that dedicated-state path; the two produce byte-identical
//! report files, enforced by `rust/tests/serve.rs` and the CI `serve
//! --once` acceptance run. Report files contain no wall-clock fields for
//! exactly this reason — timing goes to stdout only (or to the opt-in
//! `--round-stats` sidecar, written outside the report tree).
//!
//! `--fuse intra` goes one step further: instead of time-slicing the
//! workspace, each quantum step concatenates the round's per-tenant
//! batches into one `[B_total, S]` batch and runs a *single* shared base
//! forward/backward through [`Backend::fused_step`], with per-slice LoRA
//! epilogues and per-tenant adapter gradients (DESIGN.md §11). Base
//! weights are frozen under LoRA, so tenant gradients are exactly
//! separable and the intra-fused round lands bitwise where the serial run
//! lands. When a round cannot take the intra path — a non-fusable key, a
//! tenant without a detached adapter, or a backend without the fused seam
//! — it silently degrades to ordinary round fusion (the PR 8 swap path).

pub mod job;

pub use job::{group_rounds, FuseKey, JobSpec};

use crate::backend::{
    AdapterState, Backend, DeviceBatch, DeviceState, FusedSlice, MemoryCfg, StepPhases,
};
use crate::batching::{Batch, BatchStream};
use crate::coordinator::Verifier;
use crate::optim::LrSchedule;
use crate::quant::OptimStates;
use crate::report::ServeJobReport;
use crate::runtime::HostTensor;
use crate::session::resolve::{resolve, Resolved};
use crate::session::{PackingStrategy, TailPolicy, Task};
use crate::util::json::{Json, Obj};
use crate::util::toml::{TomlDoc, TomlValue};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::SystemTime;

/// How the scheduler executes a fused round (`--fuse off | on | intra`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FuseMode {
    /// Every job trains on a dedicated state (the parity reference path).
    Off,
    /// Round fusion (PR 8): compatible tenants share one workspace by
    /// swapping adapters in and out, each paying its own base pass.
    #[default]
    Round,
    /// Intra-step fusion (DESIGN.md §11): one concatenated batch, one
    /// shared base forward/backward per quantum step, per-slice adapter
    /// epilogues. Degrades to `Round` where the fused seam is unavailable.
    Intra,
}

/// Serve-mode configuration (the typed mirror of the `serve` CLI flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Watched spool directory: every `*.toml` that appears is admitted
    /// once, in lexicographic path order.
    pub spool: Option<PathBuf>,
    /// Inline job manifest (`jobs = ["a.toml", ...]`, paths relative to
    /// the manifest's directory) — the hermetic front door for CI.
    pub jobs_manifest: Option<PathBuf>,
    /// Where per-job reports and reject diagnostics land.
    pub out_dir: PathBuf,
    /// Drain the admitted queue and exit instead of watching the spool.
    pub once: bool,
    /// Stop after this many scheduling rounds, reporting partial progress.
    pub max_rounds: Option<u64>,
    /// Steps each job runs per scheduling round (the fairness quantum).
    pub steps_per_round: u64,
    /// How compatible LoRA/LoRA+ jobs share work: dedicated states,
    /// swap-based round fusion, or intra-step fused base passes.
    pub fuse: FuseMode,
    /// Seed of the shared base weights every tenant starts from.
    pub base_seed: i32,
    /// Spool poll interval in watch mode.
    pub poll_ms: u64,
    /// Opt-in per-round timing sidecar (rounds, tenants/round, rows/round,
    /// per-phase ms). Reports stay timing-free for diff-ability, so point
    /// this outside the `--out` tree.
    pub round_stats: Option<PathBuf>,
    /// AdamW m/v slot codec every tenant trains with (`--optim-states
    /// fp32|int8`). Detached adapters are converted right after init and
    /// workspaces / dedicated states are configured to match, so the
    /// adapter-swap seam carries quantized moments across rounds without
    /// a codec mismatch (swapping rejects mismatched codecs).
    pub optim_states: OptimStates,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            spool: None,
            jobs_manifest: None,
            out_dir: PathBuf::from("serve-out"),
            once: true,
            max_rounds: None,
            steps_per_round: 4,
            fuse: FuseMode::Round,
            base_seed: 0,
            poll_ms: 200,
            round_stats: None,
            optim_states: OptimStates::Fp32,
        }
    }
}

/// What one serve run did — admission accounting, round log, output files.
#[derive(Debug, Clone, Default)]
pub struct ServeSummary {
    /// Jobs that passed admission validation.
    pub admitted: usize,
    /// Job files rejected with a diagnostic file.
    pub rejected: usize,
    /// Jobs that completed their full step budget.
    pub completed: usize,
    /// Scheduling rounds executed.
    pub rounds: u64,
    /// Rounds that fused two or more tenants onto one workspace.
    pub fused_rounds: u64,
    /// Multi-tenant rounds that ran the intra-step fused path (one shared
    /// base forward/backward per quantum step).
    pub intra_fused_rounds: u64,
    /// Job ids per round, in execution order (the audit trail the
    /// grouping tests assert on).
    pub rounds_log: Vec<Vec<String>>,
    /// Report files written, in completion order.
    pub report_files: Vec<PathBuf>,
    /// Reject diagnostic files written, in admission order.
    pub reject_files: Vec<PathBuf>,
}

/// One admitted tenant's runtime state.
struct ServeJob {
    spec: JobSpec,
    resolved: Resolved,
    key: FuseKey,
    /// Detached trainable state (LoRA tenants on adapter-capable
    /// backends); swapped into a workspace for each slice.
    adapter: Option<AdapterState>,
    /// Dedicated full state for jobs that cannot share a workspace
    /// (FullFinetune, ablation/broken variants, `--fuse off`, or backends
    /// without adapter support). Created lazily on the first slice.
    dedicated: Option<DeviceState>,
    /// Staged batches, cycled by step index (the session cycle contract).
    staged: Vec<DeviceBatch>,
    /// Host copies of the staged batches, aligned with `staged`; the intra
    /// path concatenates these into one `[B_total, S]` batch per round.
    host: Vec<Batch>,
    schedule: LrSchedule,
    step: u64,
    losses: Vec<f32>,
    grad_norms: Vec<f32>,
    verifier: Verifier,
    done: bool,
    reported: bool,
}

/// The serve engine: admission queue + round scheduler + report streamer.
pub struct ServeEngine {
    backend: Arc<dyn Backend>,
    cfg: ServeConfig,
    jobs: Vec<ServeJob>,
    /// Job files already admitted or rejected (spool files are tried once).
    seen: BTreeSet<PathBuf>,
    /// One shared workspace per fuse key; looked up, never iterated, so
    /// scheduling order stays deterministic.
    workspaces: Vec<(FuseKey, DeviceState)>,
    summary: ServeSummary,
    manifest_loaded: bool,
    /// Spool directory mtime recorded after the last listing; an unchanged,
    /// settled mtime lets idle polls skip the directory read entirely.
    spool_mtime: Option<SystemTime>,
    /// Per-round timing entries for the `--round-stats` sidecar.
    round_stats_log: Vec<RoundStat>,
}

/// One `--round-stats` sidecar entry (timing lives here, never in reports).
struct RoundStat {
    round: u64,
    mode: &'static str,
    jobs: Vec<String>,
    tenants: usize,
    rows: usize,
    phases: StepPhases,
}

impl ServeEngine {
    pub fn new(backend: Arc<dyn Backend>, cfg: ServeConfig) -> Result<ServeEngine> {
        ensure!(cfg.steps_per_round > 0, "steps-per-round must be a positive step count");
        std::fs::create_dir_all(&cfg.out_dir)
            .with_context(|| format!("creating output directory {}", cfg.out_dir.display()))?;
        Ok(ServeEngine {
            backend,
            cfg,
            jobs: Vec::new(),
            seen: BTreeSet::new(),
            workspaces: Vec::new(),
            summary: ServeSummary::default(),
            manifest_loaded: false,
            spool_mtime: None,
            round_stats_log: Vec::new(),
        })
    }

    /// Admit one validated job spec: resolve the task against the backend
    /// manifest, tokenize + stage its data, and build its adapter. Errors
    /// here are admission errors — callers on the file path turn them into
    /// reject diagnostics.
    pub fn admit_spec(&mut self, spec: JobSpec) -> Result<()> {
        if self.jobs.iter().any(|j| j.spec.id == spec.id) {
            bail!("duplicate job id '{}': a job with this id was already admitted", spec.id);
        }
        let resolved = resolve(self.backend.manifest(), &spec.task)
            .with_context(|| format!("admitting job '{}'", spec.id))?;
        let exe = &resolved.spec;
        let vocab_cap = exe.model_config.vocab.max(64);
        let (examples, _stats) = spec
            .data
            .tokenized(vocab_cap, spec.loss_mode)
            .with_context(|| {
                format!("loading data for job '{}' ({})", spec.id, spec.data.label())
            })?;
        ensure!(
            !examples.is_empty(),
            "job '{}': data source {} produced no usable examples",
            spec.id,
            spec.data.label()
        );
        let batches: Vec<Batch> =
            BatchStream::new(examples, PackingStrategy::Bfd, exe.batch, exe.seq, TailPolicy::Pad)
                .collect();
        ensure!(
            !batches.is_empty(),
            "job '{}': packing produced no batches (every example exceeded the row capacity?)",
            spec.id
        );
        // stage ≤ steps distinct batches and cycle them, exactly like the
        // session's cycle mode
        let mut staged = Vec::new();
        let mut host = Vec::new();
        for b in batches.into_iter().take(spec.steps as usize) {
            staged.push(self.backend.upload_batch(&resolved.train, &b)?);
            host.push(b);
        }
        // LoRA-family tenants get a detached adapter when the backend
        // supports the swap seam; everything else (and every job on a
        // swap-less backend) falls back to a dedicated state
        let wants_adapter = matches!(
            spec.task,
            Task::Lora { .. } | Task::LoraPlus { .. } | Task::LoraNaive | Task::LoraBroken
        );
        let mut adapter = if wants_adapter {
            self.backend.init_adapter(&resolved.train, spec.seed as i32).ok()
        } else {
            None
        };
        // honor the engine's optimizer-state codec before the first step:
        // fresh adapters hold zero moments, so the conversion is legal, and
        // swap_adapter rejects codec mismatches after this point
        if self.cfg.optim_states != OptimStates::Fp32 {
            if let Some(a) = adapter.as_mut() {
                self.backend
                    .convert_adapter_optim(a, self.cfg.optim_states)
                    .with_context(|| format!("converting adapter for job '{}'", spec.id))?;
            }
        }
        let key =
            FuseKey::for_job(&spec.task, exe, self.cfg.fuse != FuseMode::Off && adapter.is_some());
        let schedule = spec.schedule.lr_schedule(spec.lr, spec.steps, spec.task.lora_plus_ratio());
        println!(
            "serve: admitted '{}' ({}, {} steps, {}, {})",
            spec.id,
            spec.task,
            spec.steps,
            spec.data.label(),
            if key.fusable { "fusable" } else { "serial" },
        );
        self.jobs.push(ServeJob {
            spec,
            resolved,
            key,
            adapter,
            dedicated: None,
            staged,
            host,
            schedule,
            step: 0,
            losses: Vec::new(),
            grad_norms: Vec::new(),
            verifier: Verifier::default(),
            done: false,
            reported: false,
        });
        self.summary.admitted += 1;
        Ok(())
    }

    /// The final trainable tensors of a tenant's detached adapter (the
    /// parity tests compare these bitwise between fused and serial runs).
    pub fn final_adapter(&self, id: &str) -> Result<Vec<HostTensor>> {
        let job = self
            .jobs
            .iter()
            .find(|j| j.spec.id == id)
            .ok_or_else(|| anyhow!("no admitted job with id '{id}'"))?;
        let adapter = job.adapter.as_ref().ok_or_else(|| {
            anyhow!("job '{id}' trains a dedicated state, not a detached adapter")
        })?;
        self.backend.adapter_params(adapter)
    }

    /// Run the service: admit, schedule rounds, stream reports. Returns
    /// when the queue is drained (`once`), the round cap is hit, or — in
    /// watch mode — never.
    pub fn run(&mut self) -> Result<ServeSummary> {
        loop {
            self.scan_sources()?;
            let pending: Vec<usize> =
                (0..self.jobs.len()).filter(|&i| !self.jobs[i].done).collect();
            if pending.is_empty() {
                if self.cfg.once {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(self.cfg.poll_ms));
                continue;
            }
            let keys: Vec<FuseKey> = pending.iter().map(|&i| self.jobs[i].key.clone()).collect();
            let mut capped = false;
            for round in group_rounds(&keys) {
                if self.cfg.max_rounds.is_some_and(|m| self.summary.rounds >= m) {
                    capped = true;
                    break;
                }
                let members: Vec<usize> = round.iter().map(|&p| pending[p]).collect();
                let ids: Vec<String> =
                    members.iter().map(|&ji| self.jobs[ji].spec.id.clone()).collect();
                self.summary.rounds_log.push(ids.clone());
                if members.len() > 1 {
                    self.summary.fused_rounds += 1;
                }
                // intra-step fusion needs the fused backend seam and a
                // detached adapter for every member; otherwise the round
                // silently degrades to swap-based round fusion
                let intra = self.cfg.fuse == FuseMode::Intra
                    && self.jobs[members[0]].key.fusable
                    && self.backend.supports_fused_step()
                    && members.iter().all(|&ji| self.jobs[ji].adapter.is_some());
                let (mode, rows, phases) = if intra {
                    if members.len() > 1 {
                        self.summary.intra_fused_rounds += 1;
                    }
                    let (rows, phases) = self.run_fused_round(&members)?;
                    ("intra", rows, phases)
                } else {
                    let mut rows = 0usize;
                    let mut phases = StepPhases::default();
                    for &ji in &members {
                        let (r, p) = self.run_slice(ji)?;
                        rows += r;
                        phases.fwd_s += p.fwd_s;
                        phases.bwd_s += p.bwd_s;
                        phases.optim_s += p.optim_s;
                    }
                    (if members.len() > 1 { "round" } else { "serial" }, rows, phases)
                };
                if self.cfg.round_stats.is_some() {
                    self.round_stats_log.push(RoundStat {
                        round: self.summary.rounds + 1,
                        mode,
                        jobs: ids,
                        tenants: members.len(),
                        rows,
                        phases,
                    });
                }
                self.summary.rounds += 1;
                for &ji in &members {
                    if self.jobs[ji].done && !self.jobs[ji].reported {
                        self.write_report(ji)?;
                    }
                }
            }
            if capped {
                break;
            }
        }
        // round cap hit (or an empty drain): every admitted job still
        // leaves a report, marked completed = false if it was cut short
        for ji in 0..self.jobs.len() {
            if !self.jobs[ji].reported {
                self.write_report(ji)?;
            }
        }
        self.write_round_stats()?;
        Ok(std::mem::take(&mut self.summary))
    }

    /// One quantum of intra-step fused rounds (DESIGN.md §11): each step
    /// concatenates the active tenants' current batches into one
    /// `[B_total, S]` batch, builds the row-slice→tenant map with each
    /// tenant's own `(step, lr, lr_b)`, and runs a single shared base
    /// forward/backward through [`Backend::fused_step`]. Tenants that
    /// exhaust their budget mid-quantum drop out of subsequent steps, so a
    /// mixed round (tenants at different schedule positions) still lands
    /// bitwise on the serial trajectory. Returns the rows processed and
    /// the summed per-phase seconds for the `--round-stats` sidecar.
    fn run_fused_round(&mut self, members: &[usize]) -> Result<(usize, StepPhases)> {
        let backend = Arc::clone(&self.backend);
        self.ensure_workspace(members[0])?;
        let key = self.jobs[members[0]].key.clone();
        let train = self.jobs[members[0]].resolved.train.clone();
        let mut rows_total = 0usize;
        let mut phases = StepPhases::default();
        for _ in 0..self.cfg.steps_per_round {
            let active: Vec<usize> = members
                .iter()
                .copied()
                .filter(|&ji| self.jobs[ji].step < self.jobs[ji].spec.steps)
                .collect();
            if active.is_empty() {
                break;
            }
            // concat batch + slice map, in fixed member (admission) order
            let seq = self.jobs[active[0]].host[0].seq;
            let mut tokens = Vec::new();
            let mut targets = Vec::new();
            let mut seg_ids = Vec::new();
            let mut pos_ids = Vec::new();
            let (mut real_tokens, mut real_targets) = (0usize, 0usize);
            let mut slices = Vec::with_capacity(active.len());
            let mut row0 = 0usize;
            for &ji in &active {
                let job = &self.jobs[ji];
                let hb = &job.host[(job.step as usize) % job.host.len()];
                ensure!(
                    hb.seq == seq,
                    "fused round mixes sequence lengths ({seq} vs {})",
                    hb.seq
                );
                tokens.extend_from_slice(hb.tokens.as_i32()?);
                targets.extend_from_slice(hb.targets.as_i32()?);
                seg_ids.extend_from_slice(hb.seg_ids.as_i32()?);
                pos_ids.extend_from_slice(hb.pos_ids.as_i32()?);
                real_tokens += hb.real_tokens;
                real_targets += hb.real_targets;
                let step_1 = job.step + 1;
                let (lr, lr_b) = job.schedule.lr_pair(step_1);
                slices.push(FusedSlice { row_start: row0, rows: hb.batch, step: step_1, lr, lr_b });
                row0 += hb.batch;
            }
            let batch = Batch {
                tokens: HostTensor::i32(tokens, vec![row0, seq]),
                targets: HostTensor::i32(targets, vec![row0, seq]),
                seg_ids: HostTensor::i32(seg_ids, vec![row0, seq]),
                pos_ids: HostTensor::i32(pos_ids, vec![row0, seq]),
                real_tokens,
                real_targets,
                batch: row0,
                seq,
            };
            // take the adapters out so the backend can mutate them while
            // the engine still borrows its own workspace table
            let mut ads: Vec<AdapterState> = active
                .iter()
                .map(|&ji| self.jobs[ji].adapter.take().expect("intra round requires adapters"))
                .collect();
            let ws = &self
                .workspaces
                .iter()
                .find(|(k, _)| *k == key)
                .expect("ensure_workspace created the shared workspace")
                .1;
            let result = backend.fused_step(&train, ws, &mut ads, &batch, &slices);
            // adapters go back before any error propagates: a failed round
            // must not orphan tenant state
            for (&ji, ad) in active.iter().zip(ads.into_iter()) {
                self.jobs[ji].adapter = Some(ad);
            }
            let out = result?;
            ensure!(
                out.tenants.len() == active.len(),
                "fused step returned {} tenant outputs for {} slices",
                out.tenants.len(),
                active.len()
            );
            for (&ji, o) in active.iter().zip(out.tenants.iter()) {
                let job = &mut self.jobs[ji];
                job.losses.push(o.loss);
                job.grad_norms.push(o.grad_norm);
                job.verifier.observe(o.loss, o.grad_norm);
                job.step += 1;
                job.reported = false;
            }
            rows_total += row0;
            phases.fwd_s += out.phases.fwd_s;
            phases.bwd_s += out.phases.bwd_s;
            phases.optim_s += out.phases.optim_s;
        }
        for &ji in members {
            if self.jobs[ji].step >= self.jobs[ji].spec.steps {
                self.jobs[ji].done = true;
            }
        }
        Ok((rows_total, phases))
    }

    /// Write the opt-in `--round-stats` timing sidecar, if configured.
    /// This is the only place serve timing touches disk — report files
    /// stay byte-diffable across fuse modes.
    fn write_round_stats(&mut self) -> Result<()> {
        let Some(path) = self.cfg.round_stats.clone() else {
            return Ok(());
        };
        let mut root = Obj::default();
        root.insert("rounds", Json::Num(self.summary.rounds as f64));
        let mut arr = Vec::new();
        for rs in &self.round_stats_log {
            let mut o = Obj::default();
            o.insert("round", Json::Num(rs.round as f64));
            o.insert("mode", Json::Str(rs.mode.to_string()));
            o.insert("jobs", Json::Arr(rs.jobs.iter().map(|j| Json::Str(j.clone())).collect()));
            o.insert("tenants", Json::Num(rs.tenants as f64));
            o.insert("rows", Json::Num(rs.rows as f64));
            o.insert("fwd_ms", Json::Num(rs.phases.fwd_s * 1e3));
            o.insert("bwd_ms", Json::Num(rs.phases.bwd_s * 1e3));
            o.insert("optim_ms", Json::Num(rs.phases.optim_s * 1e3));
            arr.push(Json::Obj(o));
        }
        root.insert("per_round", Json::Arr(arr));
        let mut text = Json::Obj(root).to_string_pretty();
        text.push('\n');
        std::fs::write(&path, text)
            .with_context(|| format!("writing round-stats sidecar {}", path.display()))?;
        self.round_stats_log.clear();
        Ok(())
    }

    /// Run one job's slice of a round: swap its adapter into the
    /// workspace, run up to `steps_per_round` ordinary train steps, swap
    /// back out. Returns the rows processed and summed per-phase seconds.
    fn run_slice(&mut self, ji: usize) -> Result<(usize, StepPhases)> {
        let backend = Arc::clone(&self.backend);
        self.ensure_workspace(ji)?;
        let quantum = self.cfg.steps_per_round;
        let ServeJob {
            spec,
            resolved,
            key,
            adapter,
            dedicated,
            staged,
            host,
            schedule,
            step,
            losses,
            grad_norms,
            verifier,
            done,
            reported,
            ..
        } = &mut self.jobs[ji];
        let ws: &mut DeviceState = if key.fusable {
            let slot = self
                .workspaces
                .iter_mut()
                .find(|(k, _)| *k == *key)
                .expect("ensure_workspace created the shared workspace");
            &mut slot.1
        } else {
            dedicated.as_mut().expect("ensure_workspace created the dedicated state")
        };
        if let Some(ad) = adapter.as_mut() {
            backend.swap_adapter(ws, ad)?;
        }
        let slice = quantum.min(spec.steps - *step);
        let mut rows = 0usize;
        let mut phases = StepPhases::default();
        for _ in 0..slice {
            let step_1 = *step + 1;
            let (lr, lr_b) = schedule.lr_pair(step_1);
            let idx = (*step as usize) % staged.len();
            let batch = &staged[idx];
            let out = backend.train_step(&resolved.train, ws, batch, step_1, lr, lr_b)?;
            losses.push(out.loss);
            grad_norms.push(out.grad_norm);
            verifier.observe(out.loss, out.grad_norm);
            rows += host[idx].batch;
            phases.fwd_s += out.phases.fwd_s;
            phases.bwd_s += out.phases.bwd_s;
            phases.optim_s += out.phases.optim_s;
            *step += 1;
            // a stepped job needs a fresh report, even if an earlier
            // (capped) run already wrote one
            *reported = false;
        }
        if let Some(ad) = adapter.as_mut() {
            backend.swap_adapter(ws, ad)?;
        }
        if *step >= spec.steps {
            *done = true;
        }
        Ok((rows, phases))
    }

    /// Make sure the state a job trains against exists: the fuse group's
    /// shared workspace, or the job's dedicated state.
    fn ensure_workspace(&mut self, ji: usize) -> Result<()> {
        let key = self.jobs[ji].key.clone();
        if key.fusable {
            if !self.workspaces.iter().any(|(k, _)| *k == key) {
                let mut st =
                    self.backend.init_state(&self.jobs[ji].resolved.init, self.cfg.base_seed)?;
                self.configure_state(&mut st)?;
                self.workspaces.push((key, st));
            }
            return Ok(());
        }
        if self.jobs[ji].dedicated.is_none() {
            // adapter jobs and FullFinetune start from the shared base
            // checkpoint; only the swap-less LoRA fallback (no adapter
            // support, no base/adapter split) seeds the whole state from
            // the tenant
            let seed = if self.jobs[ji].adapter.is_some()
                || self.jobs[ji].spec.task == Task::FullFinetune
            {
                self.cfg.base_seed
            } else {
                self.jobs[ji].spec.seed as i32
            };
            let mut st = self.backend.init_state(&self.jobs[ji].resolved.init, seed)?;
            self.configure_state(&mut st)?;
            self.jobs[ji].dedicated = Some(st);
        }
        Ok(())
    }

    /// Push the engine's optimizer-state codec onto a freshly initialized
    /// workspace or dedicated state (a no-op on the default fp32 codec).
    /// Must run before the first step so the moments are still zero, and
    /// before any adapter swap so the codecs line up.
    fn configure_state(&self, st: &mut DeviceState) -> Result<()> {
        if self.cfg.optim_states == OptimStates::Fp32 {
            return Ok(());
        }
        let mem = MemoryCfg { optim_states: self.cfg.optim_states, ..MemoryCfg::default() };
        self.backend.configure_memory(st, &mem)
    }

    /// Stream one job's report file. Deterministic by construction: no
    /// wall-clock fields, so fused and serial runs byte-match.
    fn write_report(&mut self, ji: usize) -> Result<()> {
        let (path, line) = {
            let job = &self.jobs[ji];
            let expected = job.resolved.spec.trainable_param_count;
            let verification = job.verifier.report(expected, expected);
            let rep = ServeJobReport {
                id: &job.spec.id,
                task: job.spec.task.to_string(),
                backend: self.backend.name(),
                data: job.spec.data.label(),
                steps_budget: job.spec.steps,
                steps_run: job.step,
                completed: job.done,
                losses: &job.losses,
                grad_norms: &job.grad_norms,
                verified: verification.is_training,
            };
            let path = self.cfg.out_dir.join(format!("{}.report.json", job.spec.id));
            let mut text = rep.to_json().to_string_pretty();
            text.push('\n');
            std::fs::write(&path, text)
                .with_context(|| format!("writing report {}", path.display()))?;
            let line = format!(
                "serve: '{}' {} after {} steps ({}) -> {}",
                job.spec.id,
                if job.done { "completed" } else { "stopped" },
                job.step,
                verification.status(),
                path.display(),
            );
            (path, line)
        };
        println!("{line}");
        self.summary.completed += self.jobs[ji].done as usize;
        self.summary.report_files.push(path);
        self.jobs[ji].reported = true;
        Ok(())
    }

    /// Pick up new job files: the manifest once, then the spool directory
    /// — listed only when its mtime says something changed, so idle watch
    /// polls do no per-file I/O (each file is still tried exactly once).
    fn scan_sources(&mut self) -> Result<()> {
        if let Some(man) = self.cfg.jobs_manifest.clone() {
            if !self.manifest_loaded {
                self.manifest_loaded = true;
                self.load_manifest(&man)?;
            }
        }
        if let Some(spool) = self.cfg.spool.clone() {
            let mtime = std::fs::metadata(&spool).and_then(|m| m.modified()).ok();
            let rescan = match mtime {
                Some(cur) => spool_needs_rescan(self.spool_mtime, cur, SystemTime::now()),
                // no mtime available (exotic filesystem): always list
                None => true,
            };
            if rescan {
                let mut paths: Vec<PathBuf> = std::fs::read_dir(&spool)
                    .with_context(|| format!("reading spool directory {}", spool.display()))?
                    .filter_map(|e| e.ok())
                    .map(|e| e.path())
                    .filter(|p| p.extension().and_then(|s| s.to_str()) == Some("toml"))
                    .collect();
                paths.sort();
                for p in paths {
                    if self.seen.insert(p.clone()) {
                        self.admit_file(&p);
                    }
                }
                self.spool_mtime = mtime;
            }
        }
        Ok(())
    }

    /// A malformed manifest is an operator error and fails the run —
    /// unlike per-job files, there is no useful way to degrade.
    fn load_manifest(&mut self, man: &Path) -> Result<()> {
        let text = std::fs::read_to_string(man)
            .with_context(|| format!("reading jobs manifest {}", man.display()))?;
        let doc = TomlDoc::parse(&text)
            .with_context(|| format!("parsing jobs manifest {}", man.display()))?;
        for (k, _) in &doc.entries {
            if k != "jobs" {
                bail!(
                    "unknown key '{k}' in jobs manifest {} (expected only \
                     'jobs = [\"job.toml\", ...]')",
                    man.display()
                );
            }
        }
        let jobs = doc.get("jobs").ok_or_else(|| {
            anyhow!("jobs manifest {} is missing the 'jobs = [...]' key", man.display())
        })?;
        let TomlValue::Arr(items) = jobs else {
            bail!("'jobs' in {} must be an array of job-file paths", man.display());
        };
        let base = man.parent().unwrap_or(Path::new("."));
        for item in items {
            let rel = item
                .as_str()
                .ok_or_else(|| anyhow!("'jobs' entries in {} must be strings", man.display()))?;
            let path = base.join(rel);
            if self.seen.insert(path.clone()) {
                self.admit_file(&path);
            }
        }
        Ok(())
    }

    /// Admit a job file; on any admission error, write a reject diagnostic
    /// next to the reports and keep serving.
    fn admit_file(&mut self, path: &Path) {
        let admitted = JobSpec::from_file(path)
            .with_context(|| format!("job file {}", path.display()))
            .and_then(|spec| self.admit_spec(spec));
        if let Err(e) = admitted {
            self.reject(path, &e);
        }
    }

    fn reject(&mut self, path: &Path, err: &anyhow::Error) {
        self.summary.rejected += 1;
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("job");
        let out = self.cfg.out_dir.join(format!("{stem}.reject.txt"));
        let msg = format!("rejected job file {}:\n{err:#}\n", path.display());
        eprint!("serve: {msg}");
        if let Err(w) = std::fs::write(&out, &msg) {
            eprintln!("serve: could not write reject diagnostic {}: {w}", out.display());
        }
        self.summary.reject_files.push(out);
    }
}

/// Decide whether the spool directory needs a fresh listing. `prev` is
/// the mtime recorded after the last listing, `current` its mtime now.
/// List on the first pass, whenever the mtime moved, and while `current`
/// is less than 2 s old — directory mtimes can have whole-second
/// granularity, so a file dropped in the same tick as the previous scan
/// may not move the mtime at all. A future mtime (clock skew) also lists.
fn spool_needs_rescan(prev: Option<SystemTime>, current: SystemTime, now: SystemTime) -> bool {
    let Some(prev) = prev else {
        return true;
    };
    if prev != current {
        return true;
    }
    match now.duration_since(current) {
        Ok(age) => age < std::time::Duration::from_secs(2),
        Err(_) => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn spool_rescan_skips_only_settled_unchanged_mtimes() {
        let t0 = SystemTime::UNIX_EPOCH;
        let old = t0 + Duration::from_secs(1000);
        let now = t0 + Duration::from_secs(2000);
        assert!(spool_needs_rescan(None, old, now), "first pass must list");
        assert!(
            !spool_needs_rescan(Some(old), old, now),
            "unchanged settled mtime must skip the listing"
        );
        let touched = t0 + Duration::from_secs(1500);
        assert!(spool_needs_rescan(Some(old), touched, now), "a touched directory must re-list");
        let fresh = t0 + Duration::from_secs(1999);
        assert!(
            spool_needs_rescan(Some(fresh), fresh, now),
            "a just-modified directory stays hot for the mtime-granularity window"
        );
        let future = now + Duration::from_secs(5);
        assert!(spool_needs_rescan(Some(future), future, now), "clock skew must re-list");
    }

    #[test]
    fn untouched_spool_skips_io_and_touched_spool_rescans() {
        let dir =
            std::env::temp_dir().join(format!("chronicals-spool-mtime-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mtime = |d: &Path| std::fs::metadata(d).unwrap().modified().unwrap();

        // a scan recorded the current mtime; once the granularity window
        // passes with no writes, idle polls skip the directory read
        let recorded = mtime(&dir);
        let settled_now = recorded + Duration::from_secs(10);
        assert!(
            !spool_needs_rescan(Some(recorded), mtime(&dir), settled_now),
            "untouched spool must not be re-listed"
        );

        // dropping a job file re-arms the scan: either the directory mtime
        // moved, or the write is so recent it is inside the hot window
        std::fs::write(dir.join("tenant.toml"), "id = \"t\"\n").unwrap();
        assert!(
            spool_needs_rescan(Some(recorded), mtime(&dir), SystemTime::now()),
            "touched spool must be re-listed"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
