//! Benchmark verification — the paper's §8 methodology and §4 contribution
//! ("Discovery of a benchmarking bug in Unsloth").
//!
//! A throughput number is only admissible if the run actually trained:
//! 1. gradient norms are non-zero (the 46k tok/s Unsloth figure had
//!    grad_norm == 0.0 exactly — Fig. 10),
//! 2. 100% of the expected parameters are trainable (Unsloth's broken
//!    config trained 72%),
//! 3. the loss moves (an unchanged loss means no learning signal).

/// Rolling observation of a training run's health.
#[derive(Debug, Default)]
pub struct Verifier {
    losses: Vec<f32>,
    grad_norms: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct VerificationReport {
    pub steps_observed: usize,
    pub zero_grad_steps: usize,
    pub min_grad_norm: f32,
    pub max_grad_norm: f32,
    pub loss_changed: bool,
    pub trainable_fraction: f64,
    /// Paper §9 guard: the run *ended* with a dead gradient — the final
    /// step's norm was exactly 0.0 or NaN. A run can recover from early
    /// zero-grad steps, but a dead final step means the parameters stopped
    /// moving (frozen weights, a detached graph, or numeric blow-up).
    pub final_step_grad_dead: bool,
    /// The verdict: throughput from this run is a valid training number.
    pub is_training: bool,
    pub failures: Vec<String>,
}

impl VerificationReport {
    pub fn status(&self) -> &'static str {
        if self.is_training {
            "VERIFIED"
        } else {
            "BROKEN (not training)"
        }
    }
}

impl Verifier {
    pub fn observe(&mut self, loss: f32, grad_norm: f32) {
        self.losses.push(loss);
        self.grad_norms.push(grad_norm);
    }

    pub fn report(&self, trainable_params: u64, expected_trainable: u64) -> VerificationReport {
        let zero_grad_steps = self.grad_norms.iter().filter(|&&g| g == 0.0).count();
        let min_g = self.grad_norms.iter().cloned().fold(f32::INFINITY, f32::min);
        let max_g = self.grad_norms.iter().cloned().fold(0.0f32, f32::max);
        let loss_changed = match (self.losses.first(), self.losses.last()) {
            (Some(a), Some(b)) if self.losses.len() >= 2 => (a - b).abs() > 1e-7,
            _ => false,
        };
        let trainable_fraction = if expected_trainable == 0 {
            1.0
        } else {
            trainable_params as f64 / expected_trainable as f64
        };

        let final_step_grad_dead = self
            .grad_norms
            .last()
            .is_some_and(|g| *g == 0.0 || g.is_nan());

        let mut failures = Vec::new();
        if zero_grad_steps > 0 {
            failures.push(format!(
                "gradient norm was exactly 0.0 on {zero_grad_steps}/{} steps — model is NOT training (the Unsloth-bug signature)",
                self.grad_norms.len()
            ));
        }
        if final_step_grad_dead {
            failures.push(format!(
                "final-step gradient norm is {} — the run ended with dead gradients (§9 guard: \
                 frozen weights, a detached graph, or numeric blow-up)",
                self.grad_norms.last().copied().unwrap_or(f32::NAN)
            ));
        }
        if self.losses.len() >= 2 && !loss_changed {
            failures.push("loss did not move over the run".to_string());
        }
        if trainable_fraction < 0.999 {
            failures.push(format!(
                "only {:.0}% of expected parameters are trainable",
                trainable_fraction * 100.0
            ));
        }
        VerificationReport {
            steps_observed: self.losses.len(),
            zero_grad_steps,
            min_grad_norm: if min_g.is_finite() { min_g } else { 0.0 },
            max_grad_norm: max_g,
            loss_changed,
            trainable_fraction,
            final_step_grad_dead,
            is_training: failures.is_empty() && !self.losses.is_empty(),
            failures,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_run_verifies() {
        let mut v = Verifier::default();
        for i in 0..10 {
            v.observe(5.0 - i as f32 * 0.1, 0.5);
        }
        let r = v.report(100, 100);
        assert!(r.is_training);
        assert_eq!(r.status(), "VERIFIED");
        assert!(r.failures.is_empty());
    }

    #[test]
    fn zero_grad_norm_flagged() {
        // the paper's Fig. 10 left panel: high throughput, grad_norm = 0
        let mut v = Verifier::default();
        for _ in 0..10 {
            v.observe(6.745, 0.0);
        }
        let r = v.report(100, 100);
        assert!(!r.is_training);
        assert_eq!(r.zero_grad_steps, 10);
        assert!(r.failures.iter().any(|f| f.contains("NOT training")));
    }

    #[test]
    fn partial_trainable_flagged() {
        // Unsloth's 72%-trainable configuration
        let mut v = Verifier::default();
        for i in 0..5 {
            v.observe(5.0 - i as f32 * 0.1, 0.5);
        }
        let r = v.report(72, 100);
        assert!(!r.is_training);
        assert!(r.failures.iter().any(|f| f.contains("72%")));
    }

    #[test]
    fn constant_loss_flagged() {
        let mut v = Verifier::default();
        for _ in 0..5 {
            v.observe(3.0, 0.4);
        }
        let r = v.report(100, 100);
        assert!(!r.is_training);
        assert!(r.failures.iter().any(|f| f.contains("loss did not move")));
    }

    #[test]
    fn empty_run_not_verified() {
        let v = Verifier::default();
        assert!(!v.report(1, 1).is_training);
    }

    #[test]
    fn final_step_zero_grad_flagged_even_after_healthy_steps() {
        // early steps train fine, then the gradient dies on the last step —
        // the per-step zero counter catches it, but the §9 guard names the
        // specific failure shape
        let mut v = Verifier::default();
        for i in 0..9 {
            v.observe(5.0 - i as f32 * 0.1, 0.5);
        }
        v.observe(4.1, 0.0);
        let r = v.report(100, 100);
        assert!(r.final_step_grad_dead);
        assert!(!r.is_training);
        assert!(r.failures.iter().any(|f| f.contains("final-step")), "{:?}", r.failures);
    }

    #[test]
    fn final_step_nan_grad_flagged() {
        let mut v = Verifier::default();
        for i in 0..5 {
            v.observe(5.0 - i as f32 * 0.1, 0.5);
        }
        v.observe(f32::NAN, f32::NAN);
        let r = v.report(100, 100);
        assert!(r.final_step_grad_dead);
        assert!(!r.is_training);
        // NaN is not == 0.0, so only the §9 guard catches it
        assert_eq!(r.zero_grad_steps, 0);
        assert!(r.failures.iter().any(|f| f.contains("NaN")), "{:?}", r.failures);
    }

    #[test]
    fn recovered_early_zero_grad_does_not_set_the_final_step_flag() {
        let mut v = Verifier::default();
        v.observe(5.0, 0.0); // e.g. an all-masked warmup batch
        for i in 0..5 {
            v.observe(4.9 - i as f32 * 0.1, 0.5);
        }
        let r = v.report(100, 100);
        assert!(!r.final_step_grad_dead, "healthy ending must not trip the §9 guard");
        // …but the run still fails verification on the zero-grad step count
        assert_eq!(r.zero_grad_steps, 1);
        assert!(!r.is_training);
    }
}
