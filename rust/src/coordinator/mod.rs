//! The training coordinator: step loop, gradient-flow verification (the
//! paper's benchmarking methodology, §8 "Critical Finding"), loss tracking
//! and throughput accounting.
//!
//! The coordinator is backend-agnostic: it drives the
//! [`crate::backend::Backend`] trait, so the same step loop, metering,
//! verifier and checkpoint flow serve the pure-Rust CPU reference backend
//! and the PJRT artifact runtime alike (DESIGN.md §3). Per step, exactly
//! three scalars (step, lr, lr_b) go in and three (loss, grad_norm,
//! n_tokens) come out; state advances inside the backend.

pub mod verify;

use crate::backend::{Backend, DeviceBatch, DeviceState};
use crate::batching::Batch;
use crate::checkpoint::{self, Codec};
use crate::manifest::ExecutableSpec;
use crate::metrics::{PhaseBreakdown, ThroughputMeter};
use crate::optim::LrSchedule;
use crate::runtime::HostTensor;
use anyhow::{bail, Result};
use std::path::Path;
use std::sync::Arc;
pub use verify::{VerificationReport, Verifier};

/// Per-step record (loss curve, grad norms — Fig. 17/19 inputs).
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub grad_norm: f32,
    pub n_tokens: f32,
    pub wall_ms: f64,
}

/// Final training summary (one paper-table row).
#[derive(Debug, Clone)]
pub struct TrainSummary {
    pub variant: String,
    pub steps: u64,
    pub tokens_per_sec: f64,
    pub slot_tokens_per_sec: f64,
    pub mean_step_ms: f64,
    pub std_step_ms: f64,
    pub first_loss: f32,
    pub last_loss: f32,
    pub verification: VerificationReport,
    pub param_count: u64,
    pub trainable_param_count: u64,
    /// Mean per-step phase breakdown (fwd/bwd/optim/data ms), post-warmup;
    /// `None` when no step reported phases.
    pub phases: Option<PhaseBreakdown>,
}

pub struct Trainer {
    backend: Arc<dyn Backend>,
    exe_name: String,
    spec: ExecutableSpec,
    pub state: DeviceState,
    schedule: LrSchedule,
    pub records: Vec<StepRecord>,
    meter: ThroughputMeter,
    verifier: Verifier,
    step: u64,
}

impl Trainer {
    /// Build a trainer for a train-step executable; state must come from the
    /// matching `init_*` executable (or a checkpoint) on the same backend.
    pub fn new(
        backend: Arc<dyn Backend>,
        train_exe_name: &str,
        state: DeviceState,
        schedule: LrSchedule,
        warmup_steps: usize,
    ) -> Result<Trainer> {
        let spec = backend.manifest().get(train_exe_name)?.clone();
        if spec.kind != "train" {
            bail!("'{train_exe_name}' is not a train executable");
        }
        Ok(Trainer {
            backend,
            exe_name: train_exe_name.to_string(),
            spec,
            state,
            schedule,
            records: Vec::new(),
            meter: ThroughputMeter::new(warmup_steps),
            verifier: Verifier::default(),
            step: 0,
        })
    }

    pub fn spec(&self) -> &ExecutableSpec {
        &self.spec
    }

    /// Replace the lr schedule. The session uses this before the first
    /// step when an epoch policy derives the true run length from the data
    /// plan (the step counter is untouched, so swapping mid-run rescales
    /// the remaining steps).
    pub fn set_schedule(&mut self, schedule: LrSchedule) {
        self.schedule = schedule;
    }

    pub fn backend(&self) -> &Arc<dyn Backend> {
        &self.backend
    }

    /// Stage a batch on the backend once; reusable across steps (§Perf L3:
    /// the data is identical every epoch — re-uploading it per step was the
    /// top host-side cost in the PJRT profile).
    pub fn upload_batch(&self, batch: &Batch) -> Result<DeviceBatch> {
        self.backend.upload_batch(&self.exe_name, batch)
    }

    /// Run one training step on a batch (stages the batch first; use
    /// `upload_batch` + `step_uploaded` to amortize staging across epochs).
    pub fn step(&mut self, batch: &Batch) -> Result<StepRecord> {
        let ub = self.upload_batch(batch)?;
        self.step_uploaded(&ub)
    }

    /// One training step on a pre-staged batch: the hot path.
    pub fn step_uploaded(&mut self, ub: &DeviceBatch) -> Result<StepRecord> {
        self.step += 1;
        let (lr, lr_b) = self.schedule.lr_pair(self.step);
        self.meter.step_begin();
        let out = self
            .backend
            .train_step(&self.exe_name, &mut self.state, ub, self.step, lr, lr_b)?;
        self.meter
            .step_end_phased(ub.slot_tokens() as u64, ub.real_tokens() as u64, out.phases);

        let rec = StepRecord {
            step: self.step,
            loss: out.loss,
            grad_norm: out.grad_norm,
            n_tokens: out.n_tokens,
            wall_ms: self.meter.mean_step_ms(),
        };
        self.verifier.observe(out.loss, out.grad_norm);
        self.records.push(rec);
        Ok(rec)
    }

    /// Drive a run over any batch stream: one step per batch, each batch
    /// staged once. Cycling and step-count policy belong to the caller —
    /// [`crate::session::Session::run`] pulls the lazy `BatchStream`, keeps
    /// the staged `DeviceBatch`es and cycles over them when the corpus is
    /// shorter than the run (§Perf L3: staging is amortized across epochs).
    pub fn run<I>(&mut self, batches: I) -> Result<TrainSummary>
    where
        I: IntoIterator<Item = Batch>,
    {
        let mut stepped = false;
        for b in batches {
            self.step(&b)?;
            stepped = true;
        }
        if !stepped {
            bail!("no batches");
        }
        Ok(self.summary())
    }

    pub fn summary(&self) -> TrainSummary {
        TrainSummary {
            variant: self.spec.variant.clone(),
            steps: self.step,
            tokens_per_sec: self.meter.tokens_per_sec(),
            slot_tokens_per_sec: self.meter.slot_tokens_per_sec(),
            mean_step_ms: self.meter.mean_step_ms(),
            std_step_ms: self.meter.std_step_ms(),
            first_loss: self.records.first().map(|r| r.loss).unwrap_or(f32::NAN),
            last_loss: self.records.last().map(|r| r.loss).unwrap_or(f32::NAN),
            // trainable fraction: our executables train exactly the set the
            // config declares (LoRA trains 100% of its adapters), so expected
            // == actual here; the 72%-trainable Unsloth failure mode is
            // exercised in verify.rs tests and the unsloth_bug example.
            verification: self.verifier.report(
                self.spec.trainable_param_count,
                self.spec.trainable_param_count,
            ),
            param_count: self.spec.param_count,
            trainable_param_count: self.spec.trainable_param_count,
            phases: self.meter.phase_breakdown(),
        }
    }

    /// Evaluate mean loss with a forward-only executable on current params.
    pub fn eval(&self, eval_exe_name: &str, batch: &Batch) -> Result<f32> {
        self.backend.eval_loss(eval_exe_name, &self.state, batch)
    }

    /// Pull every parameter (trainable + frozen) to host tensors, in the
    /// state order shared by all backends (the checkpoint format).
    pub fn params_to_host(&self) -> Result<Vec<HostTensor>> {
        self.backend.state_params(&self.state)
    }

    /// Restore parameters from host tensors (see `Backend::load_params`).
    pub fn load_params(&mut self, params: &[HostTensor]) -> Result<()> {
        self.backend.load_params(&mut self.state, params)
    }

    /// Save current parameters to a checkpoint file.
    pub fn save_checkpoint(&self, path: impl AsRef<Path>, codec: Codec) -> Result<()> {
        checkpoint::save(path, &self.params_to_host()?, codec)
    }

    /// Restore parameters from a checkpoint file (optimizer slots keep
    /// their current values; restart momentum by re-initializing state).
    pub fn load_checkpoint(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let tensors = checkpoint::load(path)?;
        self.load_params(&tensors)
    }

    /// Save the full train state — params + step counter + optimizer slots
    /// in their native codec — for resume-equals-continuous restarts
    /// (DESIGN.md §12). Params are always raw f32 (bit-exact), so this is
    /// exact regardless of the memory-tier configuration.
    pub fn save_train_state(&self, path: impl AsRef<Path>) -> Result<()> {
        let ts = checkpoint::TrainState {
            step: self.step,
            params: self.params_to_host()?,
            optim: self.backend.optim_snapshot(&self.state)?,
        };
        checkpoint::save_train_state(path, &ts)
    }

    /// Restore a full train state saved by [`Trainer::save_train_state`].
    /// The snapshot's optimizer codec must match the state's configured
    /// codec — fp32↔int8 migration of live moments is rejected with a real
    /// error, never silently rounded. Continuing from step k replays the
    /// continuous run bit-for-bit on the deterministic backends.
    pub fn load_train_state(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let ts = checkpoint::load_train_state(path)?;
        self.load_params(&ts.params)?;
        self.backend.load_optim_snapshot(&mut self.state, &ts.optim)?;
        self.step = ts.step;
        Ok(())
    }

    /// Last completed optimizer step (0 before training / after init).
    pub fn current_step(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::cpu::CpuBackend;
    use crate::harness;

    fn cpu_trainer(exe: &str, init: &str, seed: i32) -> Trainer {
        let backend: Arc<dyn Backend> = Arc::new(CpuBackend::new());
        let state = backend.init_state(init, seed).unwrap();
        Trainer::new(backend, exe, state, LrSchedule::constant(5e-3, 1.0), 0).unwrap()
    }

    #[test]
    fn rejects_non_train_executable() {
        let backend: Arc<dyn Backend> = Arc::new(CpuBackend::new());
        let state = backend.init_state("init_chronicals", 1).unwrap();
        let r = Trainer::new(
            backend,
            "eval_chronicals",
            state,
            LrSchedule::constant(1e-3, 1.0),
            0,
        );
        assert!(r.is_err());
    }

    #[test]
    fn step_records_accumulate() {
        let mut t = cpu_trainer("train_step_chronicals", "init_chronicals", 5);
        let (_tok, exs) = harness::build_corpus(64, 5, t.spec().model_config.vocab, 48);
        let batches =
            crate::batching::packed_batches(&exs, t.spec().batch, t.spec().seq);
        let r1 = t.step(&batches[0]).unwrap();
        let r2 = t.step(&batches[0]).unwrap();
        assert_eq!(r1.step, 1);
        assert_eq!(r2.step, 2);
        assert_eq!(t.records.len(), 2);
        assert!(r2.loss < r1.loss, "{} -> {}", r1.loss, r2.loss);
    }

    #[test]
    fn summary_before_any_step_is_nan_loss() {
        let t = cpu_trainer("train_step_chronicals", "init_chronicals", 5);
        let s = t.summary();
        assert_eq!(s.steps, 0);
        assert!(s.first_loss.is_nan());
        assert!(!s.verification.is_training);
    }
}
