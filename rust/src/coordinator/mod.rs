//! The training coordinator: step loop, gradient-flow verification (the
//! paper's benchmarking methodology, §8 "Critical Finding"), loss tracking
//! and throughput accounting.
//!
//! The hot path is: (state buffers on device) + (batch literals) →
//! `execute_b` → new state buffers + three scalar metrics. Python never
//! runs; parameters never round-trip through the host.

pub mod verify;

use crate::batching::Batch;
use crate::manifest::ExecutableSpec;
use crate::metrics::ThroughputMeter;
use crate::optim::LrSchedule;
use crate::runtime::{OutBuf, Runtime, TrainState};
use anyhow::{anyhow, bail, Result};
use std::rc::Rc;
pub use verify::{VerificationReport, Verifier};
use xla::{Literal, PjRtLoadedExecutable};

/// A batch whose four tensors already live on the device.
///
/// The source literals are kept alive alongside the buffers:
/// `BufferFromHostLiteral` is asynchronous, and the transfer may still be
/// reading host memory after the call returns (see the warning in the
/// vendored `xla_rs.cc::execute`). Dropping the literal early is a
/// use-after-free that manifests as a fatal size-check inside PJRT.
pub struct UploadedBatch {
    _lits: Vec<Literal>,
    bufs: Vec<xla::PjRtBuffer>,
    real_tokens: usize,
    slot_tokens: usize,
}

/// Per-step record (loss curve, grad norms — Fig. 17/19 inputs).
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub grad_norm: f32,
    pub n_tokens: f32,
    pub wall_ms: f64,
}

/// Final training summary (one paper-table row).
#[derive(Debug, Clone)]
pub struct TrainSummary {
    pub variant: String,
    pub steps: u64,
    pub tokens_per_sec: f64,
    pub slot_tokens_per_sec: f64,
    pub mean_step_ms: f64,
    pub std_step_ms: f64,
    pub first_loss: f32,
    pub last_loss: f32,
    pub verification: VerificationReport,
    pub param_count: u64,
    pub trainable_param_count: u64,
}

pub struct Trainer {
    rt: Rc<Runtime>,
    exe: Rc<PjRtLoadedExecutable>,
    spec: ExecutableSpec,
    pub state: TrainState,
    schedule: LrSchedule,
    pub records: Vec<StepRecord>,
    meter: ThroughputMeter,
    verifier: Verifier,
    step: u64,
}

impl Trainer {
    /// Build a trainer for a train-step executable; state must come from the
    /// matching `init_*` executable (or a checkpoint).
    pub fn new(
        rt: Rc<Runtime>,
        train_exe_name: &str,
        state: TrainState,
        schedule: LrSchedule,
        warmup_steps: usize,
    ) -> Result<Trainer> {
        let spec = rt.manifest.get(train_exe_name)?.clone();
        if spec.kind != "train" {
            bail!("'{train_exe_name}' is not a train executable");
        }
        let expected_state = spec.n_state_inputs();
        if state.buffers.len() != expected_state {
            bail!(
                "state has {} buffers, executable expects {}",
                state.buffers.len(),
                expected_state
            );
        }
        let exe = rt.compile(train_exe_name)?;
        Ok(Trainer {
            rt,
            exe,
            spec,
            state,
            schedule,
            records: Vec::new(),
            meter: ThroughputMeter::new(warmup_steps),
            verifier: Verifier::default(),
            step: 0,
        })
    }

    pub fn spec(&self) -> &ExecutableSpec {
        &self.spec
    }

    /// Upload a batch's four tensors to the device once; reusable across
    /// steps (§Perf L3: the data is identical every epoch — re-uploading it
    /// per step was the top host-side cost in the profile).
    pub fn upload_batch(&self, batch: &Batch) -> Result<UploadedBatch> {
        let lits = vec![
            batch.tokens.to_literal(&[batch.batch, batch.seq])?,
            batch.targets.to_literal(&[batch.batch, batch.seq])?,
            batch.seg_ids.to_literal(&[batch.batch, batch.seq])?,
            batch.pos_ids.to_literal(&[batch.batch, batch.seq])?,
        ];
        let mut bufs = Vec::with_capacity(4);
        for lit in &lits {
            bufs.push(
                self.rt
                    .client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("batch upload: {e:?}"))?,
            );
        }
        Ok(UploadedBatch {
            _lits: lits, // keep host memory alive past the async transfer
            bufs,
            real_tokens: batch.real_tokens,
            slot_tokens: batch.batch * batch.seq,
        })
    }

    /// Run one training step on a batch (uploads the batch first; use
    /// `upload_batch` + `step_uploaded` to amortize uploads across epochs).
    pub fn step(&mut self, batch: &Batch) -> Result<StepRecord> {
        let ub = self.upload_batch(batch)?;
        self.step_uploaded(&ub)
    }

    /// One training step on a pre-uploaded batch: the hot path. Per step
    /// only three f32 scalars (step, lr, lr_b) cross the host boundary in,
    /// and three (loss, grad_norm, n_tokens) come back out.
    pub fn step_uploaded(&mut self, ub: &UploadedBatch) -> Result<StepRecord> {
        self.step += 1;
        let (lr, lr_b) = self.schedule.lr_pair(self.step);
        let scalar_lits = [
            Literal::scalar(self.step as f32),
            Literal::scalar(lr),
            Literal::scalar(lr_b),
        ];
        let mut scalar_bufs = Vec::with_capacity(3);
        for lit in &scalar_lits {
            scalar_bufs.push(
                self.rt
                    .client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("scalar upload: {e:?}"))?,
            );
        }

        let mut args: Vec<&xla::PjRtBuffer> = self.state.input_refs();
        args.extend(ub.bufs.iter());
        args.extend(scalar_bufs.iter());

        let n_outputs = self.spec.outputs.len();
        self.meter.step_begin();
        let mut outs = self.rt.execute_buffers(&self.exe, &args, n_outputs)?;

        // last three outputs: loss, grad_norm, n_tokens
        let n_tokens_out = outs.pop().ok_or_else(|| anyhow!("missing n_tokens"))?;
        let gnorm_out = outs.pop().ok_or_else(|| anyhow!("missing grad_norm"))?;
        let loss_out = outs.pop().ok_or_else(|| anyhow!("missing loss"))?;
        let loss = loss_out.scalar_f32()?;
        let grad_norm = gnorm_out.scalar_f32()?;
        let n_tokens = n_tokens_out.scalar_f32()?;
        self.meter
            .step_end(ub.slot_tokens as u64, ub.real_tokens as u64);

        debug_assert_eq!(outs.len(), self.spec.n_state_outputs());
        self.state.apply_step_outputs(&self.rt, outs)?;

        let rec = StepRecord {
            step: self.step,
            loss,
            grad_norm,
            n_tokens,
            wall_ms: self.meter.mean_step_ms(),
        };
        self.verifier.observe(loss, grad_norm);
        self.records.push(rec);
        Ok(rec)
    }

    /// Drive a full run over batches (cycling if needed) for `steps` steps.
    /// Batches are uploaded to the device once and reused every epoch.
    pub fn run(&mut self, batches: &[Batch], steps: u64) -> Result<TrainSummary> {
        if batches.is_empty() {
            bail!("no batches");
        }
        // §Perf L3: amortize batch uploads — upload at most `steps` distinct
        // batches once, then cycle over device-resident buffers.
        let n_used = (batches.len() as u64).min(steps) as usize;
        let uploaded: Vec<UploadedBatch> = batches[..n_used]
            .iter()
            .map(|b| self.upload_batch(b))
            .collect::<Result<_>>()?;
        for i in 0..steps {
            let ub = &uploaded[(i % uploaded.len() as u64) as usize];
            self.step_uploaded(ub)?;
        }
        Ok(self.summary())
    }

    /// `run` without upload caching — the pre-optimization baseline, kept
    /// for the §Perf before/after comparison (`bench_throughput --uncached`).
    pub fn run_uncached(&mut self, batches: &[Batch], steps: u64) -> Result<TrainSummary> {
        if batches.is_empty() {
            bail!("no batches");
        }
        for i in 0..steps {
            let b = &batches[(i % batches.len() as u64) as usize];
            self.step(b)?;
        }
        Ok(self.summary())
    }

    pub fn summary(&self) -> TrainSummary {
        TrainSummary {
            variant: self.spec.variant.clone(),
            steps: self.step,
            tokens_per_sec: self.meter.tokens_per_sec(),
            slot_tokens_per_sec: self.meter.slot_tokens_per_sec(),
            mean_step_ms: self.meter.mean_step_ms(),
            std_step_ms: self.meter.std_step_ms(),
            first_loss: self.records.first().map(|r| r.loss).unwrap_or(f32::NAN),
            last_loss: self.records.last().map(|r| r.loss).unwrap_or(f32::NAN),
            // trainable fraction: our executables train exactly the set the
            // config declares (LoRA trains 100% of its adapters), so expected
            // == actual here; the 72%-trainable Unsloth failure mode is
            // exercised in verify.rs tests and the unsloth_bug example.
            verification: self.verifier.report(
                self.spec.trainable_param_count,
                self.spec.trainable_param_count,
            ),
            param_count: self.spec.param_count,
            trainable_param_count: self.spec.trainable_param_count,
        }
    }

    /// Evaluate mean loss with a forward-only executable.
    pub fn eval(&self, eval_exe_name: &str, batch: &Batch) -> Result<f32> {
        let spec = self.rt.manifest.get(eval_exe_name)?.clone();
        let exe = self.rt.compile(eval_exe_name)?;
        let n_params = spec.n_trainable + spec.n_frozen;
        let mut args: Vec<&xla::PjRtBuffer> =
            self.state.buffers[..n_params].iter().collect();
        let batch_lits = [
            batch.tokens.to_literal(&[batch.batch, batch.seq])?,
            batch.targets.to_literal(&[batch.batch, batch.seq])?,
            batch.seg_ids.to_literal(&[batch.batch, batch.seq])?,
            batch.pos_ids.to_literal(&[batch.batch, batch.seq])?,
        ];
        let mut bufs = Vec::new();
        for lit in &batch_lits {
            bufs.push(
                self.rt
                    .client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("eval upload: {e:?}"))?,
            );
        }
        args.extend(bufs.iter());
        let outs = self.rt.execute_buffers(&exe, &args, spec.outputs.len())?;
        outs[0].scalar_f32()
    }
}

/// One-shot: run a kernel microbench executable with synthetic inputs,
/// returning mean wall time per execution (used by `benches/`).
pub fn bench_kernel(
    rt: &Runtime,
    name: &str,
    reps: usize,
    warmup: usize,
) -> Result<f64> {
    let spec = rt.manifest.get(name)?.clone();
    let exe = rt.compile(name)?;
    let mut rng = crate::util::rng::Rng::new(0xC0FFEE);
    let mut lits = Vec::new();
    for inp in &spec.inputs {
        let n = inp.elements();
        let lit = match inp.dtype {
            crate::manifest::DType::F32 => {
                let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.1).collect();
                crate::runtime::HostTensor::f32(v, inp.shape.clone()).to_literal(&inp.shape)?
            }
            crate::manifest::DType::I32 => {
                let v: Vec<i32> = (0..n).map(|_| rng.range(0, 16) as i32).collect();
                crate::runtime::HostTensor::i32(v, inp.shape.clone()).to_literal(&inp.shape)?
            }
        };
        lits.push(lit);
    }
    let mut bufs = Vec::new();
    for lit in &lits {
        bufs.push(
            rt.client
                .buffer_from_host_literal(None, lit)
                .map_err(|e| anyhow!("bench upload: {e:?}"))?,
        );
    }
    let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    // outputs unknown for kernels (manifest lists []); execute and count
    let first = exe
        .execute_b(&refs)
        .map_err(|e| anyhow!("bench execute: {e:?}"))?;
    let n_out = first[0].len().max(1);
    for _ in 0..warmup {
        force(&rt.execute_buffers(&exe, &refs, n_out)?)?;
    }
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        force(&rt.execute_buffers(&exe, &refs, n_out)?)?;
    }
    Ok(t0.elapsed().as_secs_f64() / reps as f64)
}

/// Force async execution to completion by reading one output back.
fn force(outs: &[OutBuf]) -> Result<()> {
    if let Some(o) = outs.first() {
        let _ = o.to_literal()?;
    }
    Ok(())
}
