//! Regression test for the async-upload lifetime bug: `BufferFromHostLiteral`
//! reads host memory after returning, so an `UploadedBatch` must keep its
//! source literals alive (this crashed with a fatal PJRT size-check before
//! the fix). Also covers reusing one uploaded batch across steps.

use chronicals::batching::packed_batches;
use chronicals::coordinator::Trainer;
use chronicals::harness;
use chronicals::optim::LrSchedule;
use chronicals::runtime::{Runtime, TrainState};
use std::rc::Rc;

#[test]
fn uploaded_batch_survives_and_is_reusable() {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => Rc::new(rt),
        Err(_) => return, // artifacts not built
    };
    let spec = rt.manifest.get("train_step_chronicals").unwrap().clone();
    let (_tok, exs) = harness::build_corpus(256, 1, spec.model_config.vocab, 512);
    let batches = packed_batches(&exs, spec.batch, spec.seq);
    let init = harness::resolve_init(&rt, "train_step_chronicals", "init_chronicals").unwrap();
    let state = TrainState::init(&rt, &init, 1).unwrap();
    let mut trainer = Trainer::new(
        rt.clone(),
        "train_step_chronicals",
        state,
        LrSchedule::constant(1e-3, 1.0),
        0,
    )
    .unwrap();

    let ub = trainer.upload_batch(&batches[0]).unwrap();
    let r1 = trainer.step_uploaded(&ub).unwrap();
    assert!(r1.loss.is_finite() && r1.grad_norm > 0.0);
    // same uploaded batch, second step: loss must drop (state advanced)
    let r2 = trainer.step_uploaded(&ub).unwrap();
    assert!(r2.loss < r1.loss, "{} -> {}", r1.loss, r2.loss);
    // un-cached single step agrees with the uploaded path
    let r3 = trainer.step(&batches[0]).unwrap();
    assert!(r3.loss < r2.loss);
}
