//! Regression test for the async-upload lifetime bug: `BufferFromHostLiteral`
//! reads host memory after returning, so an `UploadedBatch` must keep its
//! source literals alive (this crashed with a fatal PJRT size-check before
//! the fix). Also covers reusing one staged batch across steps.
//!
//! PJRT-only (`--features pjrt`); skips loudly when artifacts are absent —
//! the CPU-backend staging equivalent lives in `integration.rs`.
#![cfg(feature = "pjrt")]

use chronicals::backend::pjrt::PjrtBackend;
use chronicals::backend::Backend;
use chronicals::coordinator::Trainer;
use chronicals::harness;
use chronicals::optim::LrSchedule;
use std::sync::Arc;

#[test]
fn uploaded_batch_survives_and_is_reusable() {
    let be: Arc<dyn Backend> = match PjrtBackend::new("artifacts") {
        Ok(be) => Arc::new(be),
        Err(e) => {
            eprintln!("SKIPPED upload lifetime (artifacts/runtime unavailable): {e:#}");
            return;
        }
    };
    let spec = be.manifest().get("train_step_chronicals").unwrap().clone();
    let (_tok, exs) = harness::build_corpus(256, 1, spec.model_config.vocab, 512);
    let batches =
        harness::make_batches(be.manifest(), "train_step_chronicals", &exs, true).unwrap();
    let init = chronicals::session::resolve_init(
        be.manifest(),
        "train_step_chronicals",
        "init_chronicals",
    )
    .unwrap();
    let state = be.init_state(&init, 1).unwrap();
    let mut trainer = Trainer::new(
        be.clone(),
        "train_step_chronicals",
        state,
        LrSchedule::constant(1e-3, 1.0),
        0,
    )
    .unwrap();

    let ub = trainer.upload_batch(&batches[0]).unwrap();
    let r1 = trainer.step_uploaded(&ub).unwrap();
    assert!(r1.loss.is_finite() && r1.grad_norm > 0.0);
    // same staged batch, second step: loss must drop (state advanced)
    let r2 = trainer.step_uploaded(&ub).unwrap();
    assert!(r2.loss < r1.loss, "{} -> {}", r1.loss, r2.loss);
    // un-staged single step agrees with the staged path
    let r3 = trainer.step(&batches[0]).unwrap();
    assert!(r3.loss < r2.loss);
}
