//! Property tests for the quantization codecs and Kahan summation:
//! randomized round-trip error bounds (paper Eq. 18, Def. 22, Prop. 5).

use chronicals::quant::*;
use chronicals::util::rng::Rng;

fn random_tensor(rng: &mut Rng, case: usize) -> Vec<f32> {
    let n = rng.range(1, 3000);
    (0..n)
        .map(|_| match case % 4 {
            0 => rng.normal() as f32,
            1 => (rng.normal() * 1e-3) as f32,
            2 => (rng.normal() * 100.0) as f32,
            _ => {
                // mixed scales inside one tensor (the §S11.1 failure mode)
                if rng.f64() < 0.5 {
                    (rng.normal() * 1e-3) as f32
                } else {
                    (rng.normal() * 10.0) as f32
                }
            }
        })
        .collect()
}

#[test]
fn prop_int8_roundtrip_bound() {
    let mut rng = Rng::new(0x18);
    for case in 0..200 {
        let x = random_tensor(&mut rng, case);
        for block in [16usize, 128, 2048] {
            let q = int8_quantize(&x, block);
            let back = int8_dequantize(&q);
            assert_eq!(back.len(), x.len());
            // per-block bound: amax_block / 127 / 2 (+ float slack)
            let n_blocks = x.len().div_ceil(block);
            for b in 0..n_blocks {
                let lo = b * block;
                let hi = ((b + 1) * block).min(x.len());
                let amax = x[lo..hi].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let bound = amax / 127.0 * 0.5 + amax * 1e-6 + 1e-9;
                for i in lo..hi {
                    assert!(
                        (x[i] - back[i]).abs() <= bound,
                        "case {case} block {block}: {} vs {}",
                        x[i],
                        back[i]
                    );
                }
            }
        }
    }
}

#[test]
fn prop_fp8_grid_idempotent() {
    // encoding an already-encoded value must be exact (grid fixpoint)
    let mut rng = Rng::new(0xF8);
    for case in 0..200 {
        let x = random_tensor(&mut rng, case);
        for fmt in [Fp8Format::E4M3, Fp8Format::E5M2] {
            let q1 = fp8_decode(&x, fmt);
            let q2 = fp8_decode(&q1, fmt);
            assert_eq!(q1, q2, "case {case} {fmt:?} not idempotent");
        }
    }
}

#[test]
fn prop_fp8_monotone_and_bounded() {
    let mut rng = Rng::new(0xF9);
    for _ in 0..2000 {
        let a = (rng.normal() * 50.0) as f32;
        let b = (rng.normal() * 50.0) as f32;
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        let ql = fp8_encode(lo, Fp8Format::E4M3);
        let qh = fp8_encode(hi, Fp8Format::E4M3);
        assert!(ql <= qh, "monotonicity broken: {lo}->{ql}, {hi}->{qh}");
        assert!(ql.abs() <= 448.0 && qh.abs() <= 448.0);
    }
}

#[test]
fn prop_kahan_at_least_as_accurate_as_naive() {
    let mut rng = Rng::new(0x4A);
    for case in 0..100 {
        let mut x = random_tensor(&mut rng, case);
        // adversarial ordering: biggest first to maximize naive cancellation
        x.sort_by(|a, b| b.abs().partial_cmp(&a.abs()).unwrap());
        let exact: f64 = x.iter().map(|&v| v as f64).sum();
        let k = kahan_sum(&x) as f64;
        let n = naive_sum(&x) as f64;
        assert!(
            (k - exact).abs() <= (n - exact).abs() + exact.abs() * 1e-7 + 1e-6,
            "case {case}: kahan {} vs naive {} (exact {exact})",
            k,
            n
        );
    }
}

#[test]
fn prop_int8_slot_adamw_update_roundtrip_bound() {
    // The int8 optimizer-state tier runs decode -> AdamW moment update ->
    // encode every step. Over 100 random steps the re-encoded moments must
    // stay within the compensated Eq. 18 slot bound of the freshly updated
    // fp32 values: the codec re-quantizes against the current amax each
    // step, so error never accumulates beyond one quantization's worth.
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    let mut rng = Rng::new(0xAD);
    for case in 0..8 {
        let n = rng.range(1, 700);
        let mut slot_m = Int8Slot::zeros(n);
        let mut slot_v = Int8Slot::zeros(n);
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let mut back = vec![0.0f32; n];
        for step in 0..100 {
            // gradient scale varies across steps to exercise re-scaling
            let scale = match (case + step) % 4 {
                0 => 1.0,
                1 => 1e-3,
                2 => 100.0,
                _ => 10.0,
            };
            let g: Vec<f32> = (0..n).map(|_| (rng.normal() * scale) as f32).collect();
            // decode the persisted states, apply the AdamW moment
            // recurrence (matching the int8 apply path, incl. the v clamp),
            // and re-encode — exactly what the training step does.
            slot_m.decode_into(&mut m);
            slot_v.decode_into(&mut v);
            for i in 0..n {
                m[i] = B1 * m[i] + (1.0 - B1) * g[i];
                v[i] = (B2 * v[i].max(0.0) + (1.0 - B2) * g[i] * g[i]).max(0.0);
            }
            slot_m.encode_from(&m);
            slot_v.encode_from(&v);

            let bound_m = int8_slot_error_bound(&m);
            slot_m.decode_into(&mut back);
            for i in 0..n {
                assert!(
                    (back[i] - m[i]).abs() <= bound_m + m[i].abs() * 1e-6 + 1e-9,
                    "case {case} step {step} m[{i}]: {} vs {} (bound {bound_m})",
                    back[i],
                    m[i]
                );
            }
            let bound_v = int8_slot_error_bound(&v);
            slot_v.decode_into(&mut back);
            for i in 0..n {
                assert!(
                    (back[i] - v[i]).abs() <= bound_v + v[i].abs() * 1e-6 + 1e-9,
                    "case {case} step {step} v[{i}]: {} vs {} (bound {bound_v})",
                    back[i],
                    v[i]
                );
            }
        }
    }
}

#[test]
fn prop_delayed_scaler_quantize_never_overflows() {
    let mut rng = Rng::new(0xD5);
    for _ in 0..50 {
        let mut s = DelayedScaler::new(32, Fp8Format::E4M3);
        for _ in 0..40 {
            let x: Vec<f32> = (0..64).map(|_| (rng.normal() * 30.0) as f32).collect();
            let (q, _scale) = s.quantize(&x);
            for v in q {
                assert!(v.is_finite() && v.abs() <= 448.0);
            }
        }
    }
}
