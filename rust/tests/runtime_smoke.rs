//! Runtime smoke: exploded tuple outputs + init state round trip.
//! (Requires `make artifacts`; skipped silently when absent.)

#[test]
fn init_outputs_are_exploded_and_readable() {
    let rt = match chronicals::runtime::Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(_) => return, // artifacts not built
    };
    if rt.manifest.get("init_lora").is_err() {
        return;
    }
    let spec = rt.manifest.get("init_lora").unwrap().clone();
    let exe = rt.compile("init_lora").unwrap();
    let outs = rt
        .execute_literals(&exe, &[xla::Literal::scalar(42i32)], spec.outputs.len())
        .unwrap();
    assert_eq!(outs.len(), spec.outputs.len());
    // every output must be individually readable
    let lit = outs[0].to_literal().unwrap();
    assert!(lit.size_bytes() > 0);
    // LoRA B params must be zero-initialized (paper §5)
    for (name, out) in spec.outputs.iter().zip(&outs) {
        if name.ends_with("_b") {
            let l = out.to_literal().unwrap();
            let v = l.to_vec::<f32>().unwrap();
            assert!(v.iter().all(|&x| x == 0.0), "{name} not zero-init");
        }
    }
}
