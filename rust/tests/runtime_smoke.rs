//! Runtime smoke: exploded tuple outputs + init state round trip.
//!
//! PJRT-only (`--features pjrt`); skips loudly when artifacts are absent —
//! the hermetic equivalents of these checks live in `integration.rs`
//! against the CPU backend.
#![cfg(feature = "pjrt")]

use chronicals::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIPPED runtime smoke (artifacts/runtime unavailable): {e:#}");
            None
        }
    }
}

#[test]
fn init_outputs_are_exploded_and_readable() {
    let Some(rt) = runtime() else { return };
    if rt.manifest.get("init_lora").is_err() {
        eprintln!("SKIPPED: manifest has no init_lora");
        return;
    }
    let spec = rt.manifest.get("init_lora").unwrap().clone();
    let exe = rt.compile("init_lora").unwrap();
    let outs = rt
        .execute_literals(&exe, &[xla::Literal::scalar(42i32)], spec.outputs.len())
        .unwrap();
    assert_eq!(outs.len(), spec.outputs.len());
    // every output must be individually readable
    let lit = outs[0].to_literal().unwrap();
    assert!(lit.size_bytes() > 0);
    // LoRA B params must be zero-initialized (paper §5)
    for (name, out) in spec.outputs.iter().zip(&outs) {
        if name.ends_with("_b") {
            let l = out.to_literal().unwrap();
            let v = l.to_vec::<f32>().unwrap();
            assert!(v.iter().all(|&x| x == 0.0), "{name} not zero-init");
        }
    }
}
