//! `chronicals serve` acceptance suite (DESIGN.md §11): the fused-vs-serial
//! determinism contract, round grouping, admission policy and the fairness
//! knobs — all hermetic on the CPU backends.
//!
//! The headline contract: a fused scheduling round (many tenants
//! time-sliced onto one shared-base workspace via adapter swaps) must be
//! bitwise identical to running the same jobs serially on dedicated
//! states — losses, grad norms and final adapter weights. Reports carry no
//! wall-clock fields, so the per-job report files must byte-match too.

use chronicals::backend::{create_backend, Backend};
use chronicals::runtime::HostTensor;
use chronicals::serve::{
    group_rounds, FuseKey, FuseMode, JobSpec, ServeConfig, ServeEngine, ServeSummary,
};
use chronicals::session::{DataSource, LossMode, Schedule, Task};
use chronicals::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// A fresh per-test output directory under the system temp dir.
fn out_dir(test: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("chronicals_serve_{test}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn tenant(id: &str, task: Task, seed: i64, data_seed: u64, steps: u64) -> JobSpec {
    JobSpec {
        id: id.to_string(),
        task,
        steps,
        lr: 5e-3,
        seed,
        schedule: Schedule::Constant,
        loss_mode: LossMode::default(),
        data: DataSource::synthetic(40, data_seed, 48),
    }
}

/// Bit patterns of a parameter list (exact f32 comparison, NaN-proof).
fn bits(params: &[HostTensor]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|t| t.as_f32().unwrap().iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// Run the two-tenant workload fused or serial; return the summary, both
/// tenants' final adapter bits and both report-file texts.
#[allow(clippy::type_complexity)]
fn run_two_tenants(
    backend_name: &str,
    fuse: FuseMode,
    dir: &Path,
) -> (ServeSummary, Vec<Vec<u32>>, Vec<Vec<u32>>, String, String) {
    let backend: Arc<dyn Backend> = create_backend(backend_name, "", 2).unwrap();
    let cfg = ServeConfig {
        out_dir: dir.to_path_buf(),
        fuse,
        steps_per_round: 2,
        ..Default::default()
    };
    let mut engine = ServeEngine::new(backend, cfg).unwrap();
    engine.admit_spec(tenant("tenant-a", Task::lora(), 7, 3, 8)).unwrap();
    engine.admit_spec(tenant("tenant-b", Task::lora_plus(16.0), 11, 5, 8)).unwrap();
    let summary = engine.run().unwrap();
    let a = bits(&engine.final_adapter("tenant-a").unwrap());
    let b = bits(&engine.final_adapter("tenant-b").unwrap());
    let ra = std::fs::read_to_string(dir.join("tenant-a.report.json")).unwrap();
    let rb = std::fs::read_to_string(dir.join("tenant-b.report.json")).unwrap();
    (summary, a, b, ra, rb)
}

fn assert_fused_matches_serial(backend_name: &str) {
    let fused_dir = out_dir(&format!("fused_{backend_name}"));
    let serial_dir = out_dir(&format!("serial_{backend_name}"));
    let (fs_sum, fa, fb, fra, frb) = run_two_tenants(backend_name, FuseMode::Round, &fused_dir);
    let (ss_sum, sa, sb, sra, srb) = run_two_tenants(backend_name, FuseMode::Off, &serial_dir);

    // the fused run actually fused: both tenants share every round
    assert!(fs_sum.fused_rounds > 0, "no fused rounds: {fs_sum:?}");
    assert!(
        fs_sum
            .rounds_log
            .iter()
            .any(|r| r == &["tenant-a".to_string(), "tenant-b".to_string()]),
        "expected a two-tenant round in {:?}",
        fs_sum.rounds_log
    );
    // the serial run never co-batched anything
    assert_eq!(ss_sum.fused_rounds, 0, "{ss_sum:?}");
    assert!(ss_sum.rounds_log.iter().all(|r| r.len() == 1), "{:?}", ss_sum.rounds_log);
    assert_eq!(fs_sum.completed, 2);
    assert_eq!(ss_sum.completed, 2);

    // the determinism contract: final adapter weights bitwise identical,
    // report files (losses + grad norms series included) byte-identical
    assert_eq!(fa, sa, "tenant-a adapters diverged on {backend_name}");
    assert_eq!(fb, sb, "tenant-b adapters diverged on {backend_name}");
    assert_eq!(fra, sra, "tenant-a reports diverged on {backend_name}");
    assert_eq!(frb, srb, "tenant-b reports diverged on {backend_name}");

    // and the jobs genuinely trained
    for text in [&fra, &frb] {
        assert!(text.contains("\"completed\": true"), "{text}");
        assert!(text.contains("\"loss_decreased\": true"), "{text}");
        assert!(text.contains("\"verified\": true"), "{text}");
    }
    let _ = std::fs::remove_dir_all(&fused_dir);
    let _ = std::fs::remove_dir_all(&serial_dir);
}

#[test]
fn fused_round_is_bitwise_identical_to_serial_on_the_reference_backend() {
    assert_fused_matches_serial("cpu");
}

// The documented parity tier for cpu-fast is a tolerance band vs the
// reference backend — but fused-vs-serial on the *same* backend runs
// identical arithmetic in identical order, so the contract holds bitwise
// there too (stronger than required).
#[test]
fn fused_round_is_bitwise_identical_to_serial_on_cpu_fast() {
    assert_fused_matches_serial("cpu-fast");
}

/// The intra-step tentpole: `--fuse intra` concatenates each round's
/// tenants into one shared base forward/backward per quantum step
/// (DESIGN.md §11). Same contract as round fusion, stated harder — final
/// adapter bits AND report bytes identical to the serial reference.
fn assert_intra_matches_serial(backend_name: &str) {
    let intra_dir = out_dir(&format!("intra_{backend_name}"));
    let serial_dir = out_dir(&format!("intra_serial_{backend_name}"));
    let (is_sum, ia, ib, ira, irb) = run_two_tenants(backend_name, FuseMode::Intra, &intra_dir);
    let (ss_sum, sa, sb, sra, srb) = run_two_tenants(backend_name, FuseMode::Off, &serial_dir);

    assert!(is_sum.intra_fused_rounds > 0, "no intra-fused rounds: {is_sum:?}");
    assert_eq!(is_sum.completed, 2);
    assert_eq!(ss_sum.completed, 2);

    assert_eq!(ia, sa, "tenant-a adapters diverged on {backend_name}");
    assert_eq!(ib, sb, "tenant-b adapters diverged on {backend_name}");
    assert_eq!(ira, sra, "tenant-a reports diverged on {backend_name}");
    assert_eq!(irb, srb, "tenant-b reports diverged on {backend_name}");
    let _ = std::fs::remove_dir_all(&intra_dir);
    let _ = std::fs::remove_dir_all(&serial_dir);
}

#[test]
fn intra_fused_round_is_bitwise_identical_to_serial_on_the_reference_backend() {
    assert_intra_matches_serial("cpu");
}

#[test]
fn intra_fused_round_is_bitwise_identical_to_serial_on_cpu_fast() {
    assert_intra_matches_serial("cpu-fast");
}

/// A ragged intra round: tenants with different step budgets share a
/// quantum — when one exhausts its budget mid-quantum the remaining
/// steps run with fewer concatenated slices, still bitwise serial.
#[test]
fn intra_fusion_is_bitwise_serial_when_a_tenant_exhausts_mid_quantum() {
    let run = |fuse: FuseMode, dir: &Path| {
        let backend: Arc<dyn Backend> = create_backend("cpu-fast", "", 2).unwrap();
        let cfg = ServeConfig {
            out_dir: dir.to_path_buf(),
            fuse,
            steps_per_round: 4,
            ..Default::default()
        };
        let mut engine = ServeEngine::new(backend, cfg).unwrap();
        engine.admit_spec(tenant("long", Task::lora(), 7, 3, 7)).unwrap();
        engine.admit_spec(tenant("short", Task::lora(), 11, 5, 5)).unwrap();
        let summary = engine.run().unwrap();
        let l = bits(&engine.final_adapter("long").unwrap());
        let s = bits(&engine.final_adapter("short").unwrap());
        (summary, l, s)
    };
    let intra_dir = out_dir("intra_ragged");
    let serial_dir = out_dir("intra_ragged_serial");
    let (is_sum, il, ish) = run(FuseMode::Intra, &intra_dir);
    let (ss_sum, sl, ssh) = run(FuseMode::Off, &serial_dir);
    // round 2 opens with long at 4/7 and short at 4/5: short drops out
    // after its fifth step and the quantum finishes on long alone
    assert!(is_sum.intra_fused_rounds > 0, "{is_sum:?}");
    assert_eq!(is_sum.completed, 2);
    assert_eq!(ss_sum.completed, 2);
    assert_eq!(il, sl, "long-tenant adapters diverged");
    assert_eq!(ish, ssh, "short-tenant adapters diverged");
    let _ = std::fs::remove_dir_all(&intra_dir);
    let _ = std::fs::remove_dir_all(&serial_dir);
}

/// A mixed intra round via staggered admission: tenant b joins after
/// tenant a already took a round, so one concatenated batch carries
/// slices at different schedule steps — under warmup, different learning
/// rates. Separability says the result is still bitwise each tenant's
/// solo serial trajectory.
#[test]
fn intra_fusion_handles_tenants_at_different_schedule_steps() {
    let spec = |id: &str, seed: i64, data_seed: u64, steps: u64| JobSpec {
        id: id.to_string(),
        task: Task::lora(),
        steps,
        lr: 5e-3,
        seed,
        schedule: Schedule::WarmupCosine { warmup: 2 },
        loss_mode: LossMode::default(),
        data: DataSource::synthetic(40, data_seed, 48),
    };
    // staggered intra run: three capped calls, b admitted after round 1
    let intra_dir = out_dir("intra_mixed");
    let backend: Arc<dyn Backend> = create_backend("cpu-fast", "", 2).unwrap();
    let cfg = ServeConfig {
        out_dir: intra_dir.clone(),
        fuse: FuseMode::Intra,
        steps_per_round: 2,
        max_rounds: Some(1),
        ..Default::default()
    };
    let mut engine = ServeEngine::new(backend, cfg).unwrap();
    engine.admit_spec(spec("a", 7, 3, 6)).unwrap();
    engine.run().unwrap(); // round 1: a alone, steps 1-2
    engine.admit_spec(spec("b", 11, 5, 4)).unwrap();
    let mid = engine.run().unwrap(); // round 2: a at steps 3-4, b at 1-2
    engine.run().unwrap(); // round 3: a at 5-6, b at 3-4 — both done
    assert!(mid.intra_fused_rounds > 0, "round 2 did not intra-fuse: {mid:?}");
    let ia = bits(&engine.final_adapter("a").unwrap());
    let ib = bits(&engine.final_adapter("b").unwrap());
    let ira = std::fs::read_to_string(intra_dir.join("a.report.json")).unwrap();
    let irb = std::fs::read_to_string(intra_dir.join("b.report.json")).unwrap();

    // serial reference: both admitted upfront, uncapped — each tenant's
    // trajectory depends only on its own steps, never on round placement
    let serial_dir = out_dir("intra_mixed_serial");
    let backend: Arc<dyn Backend> = create_backend("cpu-fast", "", 2).unwrap();
    let cfg = ServeConfig {
        out_dir: serial_dir.clone(),
        fuse: FuseMode::Off,
        steps_per_round: 2,
        ..Default::default()
    };
    let mut serial = ServeEngine::new(backend, cfg).unwrap();
    serial.admit_spec(spec("a", 7, 3, 6)).unwrap();
    serial.admit_spec(spec("b", 11, 5, 4)).unwrap();
    serial.run().unwrap();
    assert_eq!(ia, bits(&serial.final_adapter("a").unwrap()), "tenant a diverged");
    assert_eq!(ib, bits(&serial.final_adapter("b").unwrap()), "tenant b diverged");
    assert_eq!(ira, std::fs::read_to_string(serial_dir.join("a.report.json")).unwrap());
    assert_eq!(irb, std::fs::read_to_string(serial_dir.join("b.report.json")).unwrap());
    let _ = std::fs::remove_dir_all(&intra_dir);
    let _ = std::fs::remove_dir_all(&serial_dir);
}

/// The opt-in `--round-stats` sidecar carries the timing the reports must
/// not: per-round mode, tenant count, rows and per-phase milliseconds —
/// written outside the `--out` tree so report bytes stay deterministic.
#[test]
fn round_stats_sidecar_records_timing_without_touching_reports() {
    let dir = out_dir("round_stats");
    let stats = std::env::temp_dir()
        .join(format!("chronicals_serve_round_stats_{}.json", std::process::id()));
    let _ = std::fs::remove_file(&stats);
    let backend = create_backend("cpu", "", 0).unwrap();
    let cfg = ServeConfig {
        out_dir: dir.clone(),
        fuse: FuseMode::Intra,
        steps_per_round: 2,
        round_stats: Some(stats.clone()),
        ..Default::default()
    };
    let mut engine = ServeEngine::new(backend, cfg).unwrap();
    engine.admit_spec(tenant("tenant-a", Task::lora(), 7, 3, 4)).unwrap();
    engine.admit_spec(tenant("tenant-b", Task::lora(), 11, 5, 4)).unwrap();
    let summary = engine.run().unwrap();
    let json = Json::parse(&std::fs::read_to_string(&stats).unwrap()).unwrap();
    assert_eq!(json.field("rounds").unwrap().as_i64(), Some(summary.rounds as i64));
    let rounds = json.field("per_round").unwrap().as_arr().unwrap();
    assert_eq!(rounds.len(), summary.rounds as usize);
    assert_eq!(rounds[0].field("mode").unwrap().as_str(), Some("intra"));
    assert_eq!(rounds[0].field("tenants").unwrap().as_i64(), Some(2));
    assert!(rounds[0].field("fwd_ms").is_ok());
    assert!(rounds[0].field("bwd_ms").is_ok());
    assert!(rounds[0].field("optim_ms").is_ok());
    // the per-job reports stay timing-free even with the sidecar on
    let report = std::fs::read_to_string(dir.join("tenant-a.report.json")).unwrap();
    for banned in ["tokens_per_sec", "_ms", "seconds", "elapsed", "wall"] {
        assert!(!report.contains(banned), "report leaked '{banned}': {report}");
    }
    let _ = std::fs::remove_file(&stats);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn full_finetune_is_admitted_but_never_fused() {
    let dir = out_dir("fullft");
    let backend = create_backend("cpu", "", 0).unwrap();
    let cfg =
        ServeConfig { out_dir: dir.clone(), steps_per_round: 2, ..Default::default() };
    let mut engine = ServeEngine::new(backend, cfg).unwrap();
    engine.admit_spec(tenant("big", Task::FullFinetune, 7, 3, 4)).unwrap();
    engine.admit_spec(tenant("lite-a", Task::lora(), 9, 4, 4)).unwrap();
    engine.admit_spec(tenant("lite-b", Task::lora(), 13, 5, 4)).unwrap();
    let summary = engine.run().unwrap();
    assert_eq!(summary.completed, 3, "{summary:?}");
    // the full fine-tune always rides alone; the LoRA pair always fuses
    for round in &summary.rounds_log {
        if round.contains(&"big".to_string()) {
            assert_eq!(round.len(), 1, "FullFinetune co-batched: {round:?}");
        } else {
            assert_eq!(round, &["lite-a".to_string(), "lite-b".to_string()]);
        }
    }
    assert!(summary.fused_rounds > 0);
    let text = std::fs::read_to_string(dir.join("big.report.json")).unwrap();
    assert!(text.contains("\"task\": \"task full-ft\""), "{text}");
    assert!(text.contains("\"loss_decreased\": true"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn duplicate_job_ids_are_rejected_at_admission() {
    let dir = out_dir("dup");
    let backend = create_backend("cpu", "", 0).unwrap();
    let cfg = ServeConfig { out_dir: dir.clone(), ..Default::default() };
    let mut engine = ServeEngine::new(backend, cfg).unwrap();
    engine.admit_spec(tenant("tenant-a", Task::lora(), 7, 3, 4)).unwrap();
    let err = engine.admit_spec(tenant("tenant-a", Task::lora(), 8, 4, 4)).unwrap_err();
    assert!(format!("{err:#}").contains("duplicate job id"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spool_rejects_malformed_and_duplicate_jobs_with_diagnostics() {
    let dir = out_dir("spool_out");
    let spool = out_dir("spool_in");
    std::fs::create_dir_all(&spool).unwrap();
    // admitted in lexicographic order: a_good, b_dup (same id), c_bad
    std::fs::write(spool.join("a_good.toml"), "id = \"spool-tenant\"\nsteps = 4\n").unwrap();
    std::fs::write(spool.join("b_dup.toml"), "id = \"spool-tenant\"\nsteps = 4\n").unwrap();
    std::fs::write(spool.join("c_bad.toml"), "id = \"oops\"\nspeed = 9\n").unwrap();
    let backend = create_backend("cpu", "", 0).unwrap();
    let cfg = ServeConfig {
        spool: Some(spool.clone()),
        out_dir: dir.clone(),
        ..Default::default()
    };
    let mut engine = ServeEngine::new(backend, cfg).unwrap();
    let summary = engine.run().unwrap();
    assert_eq!(summary.admitted, 1, "{summary:?}");
    assert_eq!(summary.rejected, 2, "{summary:?}");
    assert_eq!(summary.completed, 1, "{summary:?}");
    assert!(dir.join("spool-tenant.report.json").exists());
    let dup = std::fs::read_to_string(dir.join("b_dup.reject.txt")).unwrap();
    assert!(dup.contains("duplicate job id"), "{dup}");
    let bad = std::fs::read_to_string(dir.join("c_bad.reject.txt")).unwrap();
    assert!(bad.contains("unknown key 'speed'"), "{bad}");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&spool);
}

#[test]
fn max_rounds_stops_the_server_and_reports_partial_progress() {
    let dir = out_dir("maxrounds");
    let backend = create_backend("cpu", "", 0).unwrap();
    let cfg = ServeConfig {
        out_dir: dir.clone(),
        steps_per_round: 2,
        max_rounds: Some(3),
        ..Default::default()
    };
    let mut engine = ServeEngine::new(backend, cfg).unwrap();
    engine.admit_spec(tenant("long-job", Task::lora(), 7, 3, 50)).unwrap();
    let summary = engine.run().unwrap();
    assert_eq!(summary.rounds, 3, "{summary:?}");
    assert_eq!(summary.completed, 0, "{summary:?}");
    let text = std::fs::read_to_string(dir.join("long-job.report.json")).unwrap();
    let json = Json::parse(&text).unwrap();
    assert_eq!(json.field("completed").unwrap().as_bool(), Some(false));
    assert_eq!(json.field("steps_run").unwrap().as_i64(), Some(6));
    assert_eq!(json.field("steps_budget").unwrap().as_i64(), Some(50));
    assert_eq!(json.field("losses").unwrap().as_arr().unwrap().len(), 6);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn per_job_step_budgets_are_exact() {
    let dir = out_dir("budget");
    let backend = create_backend("cpu", "", 0).unwrap();
    let cfg =
        ServeConfig { out_dir: dir.clone(), steps_per_round: 4, ..Default::default() };
    let mut engine = ServeEngine::new(backend, cfg).unwrap();
    engine.admit_spec(tenant("five", Task::lora(), 7, 3, 5)).unwrap();
    let summary = engine.run().unwrap();
    // 4 steps in the first round, the 1 remaining in the second
    assert_eq!(summary.rounds, 2, "{summary:?}");
    assert_eq!(summary.completed, 1);
    let text = std::fs::read_to_string(dir.join("five.report.json")).unwrap();
    let json = Json::parse(&text).unwrap();
    assert_eq!(json.field("steps_run").unwrap().as_i64(), Some(5));
    assert_eq!(json.field("completed").unwrap().as_bool(), Some(true));
    assert_eq!(json.field("losses").unwrap().as_arr().unwrap().len(), 5);
    assert_eq!(json.field("grad_norms").unwrap().as_arr().unwrap().len(), 5);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn geometry_mismatched_keys_never_share_a_round() {
    let key = |fusable: bool, seq: usize| FuseKey {
        fusable,
        family: "lora".into(),
        batch: 4,
        seq,
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 64,
        lora_rank: 4,
        lora_alpha: 8,
    };
    // two geometries interleaved + one unfusable: three rounds, grouped
    // by key in admission order, never silently co-batched
    let rounds = group_rounds(&[
        key(true, 64),
        key(true, 128),
        key(true, 64),
        key(false, 64),
        key(true, 128),
    ]);
    assert_eq!(rounds, vec![vec![0, 2], vec![1, 4], vec![3]]);
}

/// The serve seam's init contract, through the `Backend` trait on both CPU
/// backends: a tenant adapter is bitwise the trainable prefix of a full
/// `init_state` at the same seed — that is what makes "fused round" and
/// "fresh dedicated session" interchangeable.
#[test]
fn init_adapter_matches_init_state_trainable_prefix_on_both_backends() {
    for name in ["cpu", "cpu-fast"] {
        let backend = create_backend(name, "", 1).unwrap();
        let state = backend.init_state("init_lora", 42).unwrap();
        let full = backend.state_params(&state).unwrap();
        let adapter = backend.init_adapter("train_step_lora", 42).unwrap();
        let params = backend.adapter_params(&adapter).unwrap();
        let spec = backend.manifest().get("train_step_lora").unwrap();
        assert_eq!(params.len(), spec.n_trainable, "{name}");
        for (i, (a, f)) in params.iter().zip(full.iter()).enumerate() {
            assert_eq!(bits(&[a.clone()]), bits(&[f.clone()]), "{name} trainable tensor {i}");
        }
        // full fine-tuning has no detached adapter: the trait says so
        let err = backend.init_adapter("train_step_chronicals", 0).unwrap_err();
        assert!(format!("{err:#}").contains("LoRA"), "{err:#}");
    }
}
