//! Property tests for the shuffle/epoch policy (ISSUE 5):
//!
//! * for any example set, every shuffled epoch carries exactly the same
//!   token multiset as the unshuffled plan — shuffling is a plan
//!   permutation, it can neither lose nor duplicate an example;
//! * `shuffle: None` is bitwise identical to the legacy single-pass
//!   stream, for every packing strategy;
//! * epoch-mode sessions derive their run length from the data and are
//!   bitwise reproducible.

use chronicals::backend::cpu::CpuBackend;
use chronicals::backend::Backend;
use chronicals::batching::{Batch, BatchStream, EpochSpec, PackingStrategy, TailPolicy};
use chronicals::data::TokenizedExample;
use chronicals::harness;
use chronicals::session::{DataSource, EpochPolicy, SessionBuilder, Task};
use chronicals::util::rng::Rng;
use std::sync::Arc;

fn cpu() -> Arc<dyn Backend> {
    Arc::new(CpuBackend::new())
}

/// Random example set with lengths bounded by `max_len` (so nothing is
/// oversized at the stream's row capacity).
fn random_examples(seed: u64, n: usize, max_len: usize) -> Vec<TokenizedExample> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = rng.range(1, max_len + 1);
            let tokens: Vec<i32> = (0..len).map(|_| rng.range(4, 64) as i32).collect();
            let mut targets: Vec<i32> = tokens.iter().skip(1).copied().collect();
            targets.push(-1);
            TokenizedExample { tokens, targets }
        })
        .collect()
}

/// All real (segment ≠ 0) token ids a batch carries.
fn real_tokens_of(b: &Batch) -> Vec<i32> {
    let toks = b.tokens.as_i32().unwrap();
    let segs = b.seg_ids.as_i32().unwrap();
    toks.iter().zip(segs).filter(|(_, &s)| s != 0).map(|(&t, _)| t).collect()
}

#[test]
fn shuffled_epoch_token_multiset_equals_unshuffled() {
    for (case, (seed, n, batch, seq)) in
        [(1u64, 7usize, 2usize, 16usize), (2, 40, 4, 32), (3, 93, 3, 24), (4, 256, 4, 48)]
            .into_iter()
            .enumerate()
    {
        for strategy in [
            PackingStrategy::Bfd,
            PackingStrategy::Ffd,
            PackingStrategy::NextFit,
            PackingStrategy::Padded,
        ] {
            let exs = random_examples(seed, n, seq - 1);
            let mut expected: Vec<i32> = exs.iter().flat_map(|e| e.tokens.clone()).collect();
            expected.sort_unstable();

            let epochs = 3usize;
            let per_epoch =
                BatchStream::new(exs.clone(), strategy, batch, seq, TailPolicy::Pad)
                    .n_batches();
            let all: Vec<Batch> = BatchStream::with_epochs(
                exs,
                strategy,
                batch,
                seq,
                TailPolicy::Pad,
                EpochSpec { shuffle: Some(seed ^ 0xABCD), epochs: epochs as u64 },
            )
            .collect();
            assert_eq!(all.len(), epochs * per_epoch, "case {case} {strategy:?}");
            for e in 0..epochs {
                let mut got: Vec<i32> = all[e * per_epoch..(e + 1) * per_epoch]
                    .iter()
                    .flat_map(real_tokens_of)
                    .collect();
                got.sort_unstable();
                assert_eq!(
                    got, expected,
                    "case {case} {strategy:?} epoch {e}: an example was lost or duplicated"
                );
            }
        }
    }
}

#[test]
fn no_shuffle_is_bitwise_identical_to_legacy_for_every_strategy() {
    // a real tokenized corpus, not synthetic ids
    let (_tok, exs) = harness::build_corpus(128, 11, 64, 48);
    for strategy in [
        PackingStrategy::Bfd,
        PackingStrategy::Ffd,
        PackingStrategy::NextFit,
        PackingStrategy::Padded,
    ] {
        for tail in [TailPolicy::Pad, TailPolicy::Drop] {
            let legacy: Vec<Batch> =
                BatchStream::new(exs.clone(), strategy, 4, 64, tail).collect();
            let explicit: Vec<Batch> = BatchStream::with_epochs(
                exs.clone(),
                strategy,
                4,
                64,
                tail,
                EpochSpec { shuffle: None, epochs: 1 },
            )
            .collect();
            assert_eq!(legacy.len(), explicit.len(), "{strategy:?} {tail:?}");
            for (a, b) in legacy.iter().zip(&explicit) {
                assert_eq!(a.tokens, b.tokens, "{strategy:?} {tail:?}");
                assert_eq!(a.targets, b.targets);
                assert_eq!(a.seg_ids, b.seg_ids);
                assert_eq!(a.pos_ids, b.pos_ids);
                assert_eq!(a.real_tokens, b.real_tokens);
                assert_eq!(a.real_targets, b.real_targets);
            }
        }
    }
}

#[test]
fn default_policy_session_is_bitwise_stable_and_shuffle_changes_order_only() {
    let run = |policy: EpochPolicy| {
        let mut s = SessionBuilder::new()
            .task(Task::FullFinetune)
            .steps(10)
            .lr(5e-3)
            .seed(3)
            .data(DataSource::synthetic(96, 3, 48))
            .epoch_policy(policy)
            .on_backend(cpu())
            .build()
            .unwrap();
        s.run().unwrap()
    };
    let a = run(EpochPolicy::default());
    let b = run(EpochPolicy::default());
    assert_eq!(
        a.summary.last_loss.to_bits(),
        b.summary.last_loss.to_bits(),
        "default policy must be deterministic"
    );

    let s1 = run(EpochPolicy { shuffle: Some(7), epochs: None });
    let s2 = run(EpochPolicy { shuffle: Some(7), epochs: None });
    assert_eq!(
        s1.summary.last_loss.to_bits(),
        s2.summary.last_loss.to_bits(),
        "shuffled runs must be reproducible at a fixed seed"
    );
    // shuffling permutes the plan but cannot change what was planned
    assert_eq!(a.examples, s1.examples);
    assert_eq!(a.batches_planned, s1.batches_planned);
    assert_eq!(a.oversized_dropped, s1.oversized_dropped);
    assert_eq!(a.packed_density.to_bits(), s1.packed_density.to_bits());
    assert_eq!(a.padding_recovery.to_bits(), s1.padding_recovery.to_bits());
}

#[test]
fn epoch_mode_run_length_follows_the_data() {
    let mut s = SessionBuilder::new()
        .task(Task::FullFinetune)
        .lr(5e-3)
        .seed(5)
        .data(DataSource::synthetic(64, 5, 48))
        .epochs(2)
        .shuffle_seed(9)
        .on_backend(cpu())
        .build()
        .unwrap();
    let report = s.run().unwrap();
    assert_eq!(report.epochs, 2);
    assert_eq!(report.summary.steps as usize, report.batches_planned);
    assert_eq!(report.batches_planned % 2, 0, "two epochs emit an even batch total");
    assert_eq!(report.batches_staged, report.batches_planned);
    assert!(report.summary.verification.is_training);

    // bitwise reproducible across two fresh sessions
    let mut s2 = SessionBuilder::new()
        .task(Task::FullFinetune)
        .lr(5e-3)
        .seed(5)
        .data(DataSource::synthetic(64, 5, 48))
        .epochs(2)
        .shuffle_seed(9)
        .on_backend(cpu())
        .build()
        .unwrap();
    let report2 = s2.run().unwrap();
    assert_eq!(
        report.summary.last_loss.to_bits(),
        report2.summary.last_loss.to_bits()
    );
    assert_eq!(
        report.summary.verification.max_grad_norm.to_bits(),
        report2.summary.verification.max_grad_norm.to_bits()
    );
}
