//! Golden-file test for the file-backed data pipeline (ISSUE 5).
//!
//! Tokenizes the checked-in `data/sample.jsonl` with a fixed seed and pins
//! the learned vocabulary shape, the first example's decoded text, the
//! emoji record's surrogate-pair round trip, the source accounting
//! (malformed / truncated) and the BFD plan's internal consistency
//! (planned tokens, bins, batches → density / padding recovery).
//!
//! The decode-level pins survive merge-table churn (decode is a pure byte
//! concatenation of normalized text), while the vocab-shape and accounting
//! pins trip LOUDLY on any change to learning order, tie-breaking or the
//! corpus file. If a change is intentional, rerun the suite and copy the
//! printed actual values over the constants below.

use chronicals::batching::{BatchStream, PackingStrategy, TailPolicy};
use chronicals::data_source::{ByteBpe, JsonlSource, Tokenizer};
use chronicals::session::ExampleSource;
use std::path::PathBuf;

/// The golden parameters: seed 7, model vocab cap 64, source token cap 96,
/// reference geometry B=4 / S=64.
const SEED: u64 = 7;
const VOCAB_CAP: usize = 64;
const MAX_SEQ: usize = 96;
const B: usize = 4;
const S: usize = 64;

/// Pinned: corpus shape (43 lines = 41 records + 2 malformed).
const N_EXAMPLES: usize = 41;
const N_MALFORMED: usize = 2;
/// Pinned: learned vocabulary. The alphabet is 33 bytes — space, comma,
/// period, a–z, and the four UTF-8 bytes of 😀 (`F0 9F 98 80`) from the
/// surrogate-pair record — so 27 merges fill the 64-id cap.
const VOCAB_SIZE: usize = 64;
const N_ALPHABET: usize = 33;
const N_MERGES: usize = VOCAB_SIZE - 4 - N_ALPHABET;
/// Pinned: the first record decodes back to its normalized text,
/// `{"prompt": "explain packing .", "completion": "bins share rows ."}`,
/// with per-part `<bos>`/`<eos>` framing. Decoding is byte concatenation,
/// so this pin is exact whatever the merge table looks like.
const EX0_DECODED: &str = "<bos>explain packing .<eos><bos>bins share rows .<eos>";
const EX0_COMPLETION_DECODED: &str = "<bos>bins share rows .<eos>";
/// Pinned: the final record is the emoji pair, written in the JSONL file
/// as the escaped surrogate pair `😀`.
const EMOJI_COMPLETION_DECODED: &str = "<bos>surrogate pairs combine , the smile survives .<eos>";

fn sample_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../data/sample.jsonl")
}

#[test]
fn golden_tokenization_and_accounting() {
    let src = JsonlSource::new(sample_path(), SEED, MAX_SEQ);
    let exs = src.examples(VOCAB_CAP).unwrap();
    let stats = src.stats();

    println!("examples: {}", exs.len());
    println!("malformed: {} truncated: {}", stats.malformed, stats.truncated);
    println!("lengths: {:?}", exs.iter().map(|e| e.len()).collect::<Vec<_>>());

    assert_eq!(exs.len(), N_EXAMPLES);
    assert_eq!(stats.malformed, N_MALFORMED);
    // the long ramble records truncate; the exact count may shift by one
    // when the merge table changes, but it must stay small and non-zero
    assert!(
        (2..=4).contains(&stats.truncated),
        "truncated {} out of expected range",
        stats.truncated
    );
    // the two malformed lines carry file:line diagnostics
    assert_eq!(stats.notes.len(), N_MALFORMED, "{:?}", stats.notes);
    assert!(stats.notes[0].contains("sample.jsonl:11:"), "{:?}", stats.notes);
    assert!(stats.notes[1].contains("sample.jsonl:22:"), "{:?}", stats.notes);
    // every id respects the model vocab cap
    for ex in &exs {
        for &t in &ex.tokens {
            assert!((0..VOCAB_CAP as i32).contains(&t), "token {t} out of range");
        }
        assert!(ex.len() <= MAX_SEQ);
    }

    // the learned vocabulary itself, via the persistence path
    let vocab_path = std::env::temp_dir().join("chronicals_golden.vocab");
    std::fs::remove_file(&vocab_path).ok();
    let persisted = JsonlSource::new(sample_path(), SEED, MAX_SEQ).with_vocab_file(&vocab_path);
    let exs2 = persisted.examples(VOCAB_CAP).unwrap();
    let tok = ByteBpe::load(&vocab_path).unwrap();
    std::fs::remove_file(&vocab_path).ok();
    println!("vocab: {} merges: {}", tok.vocab_size(), tok.n_merges());
    assert_eq!(tok.vocab_size(), VOCAB_SIZE);
    assert_eq!(tok.n_merges(), N_MERGES);
    assert_eq!(tok.seed(), SEED);
    // persisting the vocab must not change tokenization; two independent
    // reads of the corpus must be bitwise identical
    assert_eq!(exs.len(), exs2.len());
    for (a, b) in exs.iter().zip(&exs2) {
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.targets, b.targets);
    }

    // ex0 decode pin: tokens round-trip to the normalized record text, and
    // the supervised targets are exactly the completion's encoding
    assert_eq!(tok.decode(&exs[0].tokens), EX0_DECODED);
    let ex0_supervised: Vec<i32> =
        exs[0].targets.iter().copied().filter(|&t| t >= 0).collect();
    assert_eq!(tok.decode(&ex0_supervised), EX0_COMPLETION_DECODED);
    assert_eq!(exs[0].real_targets(), ex0_supervised.len());
    assert_eq!(exs[0].targets[0], -1, "prompt start must be loss-masked");
    assert_eq!(*exs[0].targets.last().unwrap(), -1, "final position predicts nothing");

    // the emoji record (last line, escaped 😀 in the file) must
    // survive JSONL parse → tokenize → decode intact
    let emoji = exs.last().unwrap();
    let decoded = tok.decode(&emoji.tokens);
    println!("emoji decode: {decoded}");
    assert!(decoded.contains('\u{1f600}'), "😀 lost in the pipeline: {decoded}");
    assert_eq!(
        decoded,
        "<bos>decode the emoji \u{1f600} please .<eos>\
         <bos>surrogate pairs combine , the smile survives .<eos>"
    );
    let emoji_supervised: Vec<i32> =
        emoji.targets.iter().copied().filter(|&t| t >= 0).collect();
    assert_eq!(tok.decode(&emoji_supervised), EMOJI_COMPLETION_DECODED);
    // nothing in the corpus falls back to <unk> or mojibake — the learned
    // alphabet covers every byte, emoji included
    for ex in &exs {
        let d = tok.decode(&ex.tokens);
        assert!(!d.contains('\u{fffd}'), "replacement char in {d}");
        assert!(!d.contains("<unk>"), "unknown byte in {d}");
    }
}

#[test]
fn golden_packing_plan() {
    let src = JsonlSource::new(sample_path(), SEED, MAX_SEQ);
    let exs = src.examples(VOCAB_CAP).unwrap();
    let lens: Vec<usize> = exs.iter().map(|e| e.len()).collect();
    let n_oversized = lens.iter().filter(|&&l| l > S).count();
    let packable: Vec<usize> = lens.iter().copied().filter(|&l| l <= S).collect();
    let padded_tokens: usize = packable.iter().sum();
    let stream = BatchStream::new(exs, PackingStrategy::Bfd, B, S, TailPolicy::Pad);

    println!(
        "bins: {} oversized: {} planned: {} batches: {} padded_tokens: {padded_tokens}",
        stream.n_bins(),
        stream.oversized_dropped(),
        stream.planned_tokens(),
        stream.n_batches(),
    );

    // plan accounting is internally consistent with the example lengths:
    // every packable token is planned exactly once, oversized records are
    // the only drops, and bins divide into ceil(bins / B) batches
    assert_eq!(stream.oversized_dropped(), n_oversized);
    assert_eq!(stream.planned_tokens(), padded_tokens);
    assert_eq!(stream.n_batches(), stream.n_bins().div_ceil(B));
    // BFD can never beat the volume lower bound nor pad rows into thin air
    assert!(stream.n_bins() >= padded_tokens.div_ceil(S));
    assert!(stream.n_bins() <= packable.len());
    assert_eq!(stream.tail_padded(), stream.n_bins() % B != 0);

    // a second plan over a fresh read is bitwise identical
    let src2 = JsonlSource::new(sample_path(), SEED, MAX_SEQ);
    let stream2 =
        BatchStream::new(src2.examples(VOCAB_CAP).unwrap(), PackingStrategy::Bfd, B, S, TailPolicy::Pad);
    assert_eq!(stream2.n_bins(), stream.n_bins());
    assert_eq!(stream2.planned_tokens(), stream.planned_tokens());

    // density / padding recovery exactly as Session::run derives them —
    // packing the varied-length sample corpus must recover real padding
    let density =
        stream.planned_tokens() as f64 / (stream.n_batches() * B * S) as f64;
    let waste_padded = 1.0 - padded_tokens as f64 / (packable.len() * S) as f64;
    let waste_packed =
        1.0 - stream.planned_tokens() as f64 / (stream.n_bins() * S) as f64;
    let recovery = (waste_padded - waste_packed) / waste_padded;
    println!("density: {density:.6} recovery: {recovery:.6}");
    assert!(density > 0.5, "density {density}");
    assert!(recovery > 0.3, "the sample corpus must show real padding recovery ({recovery})");
}
