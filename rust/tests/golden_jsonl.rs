//! Golden-file test for the file-backed data pipeline (ISSUE 5).
//!
//! Tokenizes the checked-in `data/sample.jsonl` with a fixed seed and pins
//! the learned vocabulary shape, the first example's exact token ids, the
//! source accounting (malformed / truncated) and the BFD plan accounting
//! (bins, oversized drops, packed tokens → density / padding recovery).
//!
//! Any change to the tokenizer's learning order, tie-breaking, chunking or
//! encoding — or to the packing plan — trips these assertions LOUDLY. If
//! the change is intentional, rerun the suite and copy the printed actual
//! values over the constants below (they are all printed on failure).

use chronicals::batching::{BatchStream, PackingStrategy, TailPolicy};
use chronicals::data_source::{ByteBpe, JsonlSource, Tokenizer};
use chronicals::session::ExampleSource;
use std::path::PathBuf;

/// The golden parameters: seed 7, model vocab cap 64, source token cap 96,
/// reference geometry B=4 / S=64.
const SEED: u64 = 7;
const VOCAB_CAP: usize = 64;
const MAX_SEQ: usize = 96;
const B: usize = 4;
const S: usize = 64;

/// Pinned: corpus shape.
const N_EXAMPLES: usize = 40;
const N_MALFORMED: usize = 2;
const N_TRUNCATED: usize = 2;
/// Pinned: learned vocabulary (4 specials + 29-byte alphabet + 31 merges).
const VOCAB_SIZE: usize = 64;
const N_MERGES: usize = 31;
/// Pinned: the exact token ids of the first record,
/// `{"prompt": "explain packing .", "completion": "bins share rows ."}`.
const EX0_TOKENS: &[i32] = &[
    2, 5, 29, 14, 16, 8, 34, 39, 60, 26, 37, 33, 3, 2, 22, 34, 7, 41, 13, 8, 40, 4, 57, 23, 7,
    33, 3,
];
/// Pinned: the first record's prompt occupies 13 tokens, so 14 of its 27
/// positions are supervised.
const EX0_REAL_TARGETS: usize = 14;
/// Pinned: BFD plan at row capacity 64.
const N_BINS: usize = 28;
const N_OVERSIZED: usize = 3;
const PLANNED_TOKENS: usize = 1489;
const BATCHES_PER_EPOCH: usize = 7;
/// Pinned: Σ len over the packable (len ≤ S) examples — the
/// padded-baseline numerator. Oversized examples are excluded from the
/// baseline exactly as the packing plan excludes them, so both waste
/// figures cover the same 37-example corpus.
const PADDED_TOKENS: usize = 1489;
const PADDED_ROWS: usize = N_EXAMPLES - N_OVERSIZED;

fn sample_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../data/sample.jsonl")
}

#[test]
fn golden_tokenization_and_accounting() {
    let src = JsonlSource::new(sample_path(), SEED, MAX_SEQ);
    let exs = src.examples(VOCAB_CAP).unwrap();
    let stats = src.stats();

    println!("examples: {}", exs.len());
    println!("malformed: {} truncated: {}", stats.malformed, stats.truncated);
    println!("ex0 tokens: {:?}", exs[0].tokens);
    println!("ex0 real_targets: {}", exs[0].real_targets());
    println!("lengths: {:?}", exs.iter().map(|e| e.len()).collect::<Vec<_>>());

    assert_eq!(exs.len(), N_EXAMPLES);
    assert_eq!(stats.malformed, N_MALFORMED);
    assert_eq!(stats.truncated, N_TRUNCATED);
    assert_eq!(exs[0].tokens, EX0_TOKENS, "tokenizer output changed — see module docs");
    assert_eq!(exs[0].real_targets(), EX0_REAL_TARGETS);
    // the two malformed lines carry file:line diagnostics
    assert_eq!(stats.notes.len(), N_MALFORMED, "{:?}", stats.notes);
    assert!(stats.notes[0].contains("sample.jsonl:11:"), "{:?}", stats.notes);
    assert!(stats.notes[1].contains("sample.jsonl:22:"), "{:?}", stats.notes);
    // every id respects the model vocab cap
    for ex in &exs {
        for &t in &ex.tokens {
            assert!((0..VOCAB_CAP as i32).contains(&t), "token {t} out of range");
        }
        assert!(ex.len() <= MAX_SEQ);
    }

    // the learned vocabulary itself, via the persistence path
    let vocab_path = std::env::temp_dir().join("chronicals_golden.vocab");
    std::fs::remove_file(&vocab_path).ok();
    let persisted = JsonlSource::new(sample_path(), SEED, MAX_SEQ).with_vocab_file(&vocab_path);
    let exs2 = persisted.examples(VOCAB_CAP).unwrap();
    let tok = ByteBpe::load(&vocab_path).unwrap();
    std::fs::remove_file(&vocab_path).ok();
    println!("vocab: {} merges: {}", tok.vocab_size(), tok.n_merges());
    assert_eq!(tok.vocab_size(), VOCAB_SIZE);
    assert_eq!(tok.n_merges(), N_MERGES);
    assert_eq!(tok.seed(), SEED);
    // persisting the vocab must not change tokenization
    for (a, b) in exs.iter().zip(&exs2) {
        assert_eq!(a.tokens, b.tokens);
    }
}

#[test]
fn golden_packing_plan() {
    let src = JsonlSource::new(sample_path(), SEED, MAX_SEQ);
    let exs = src.examples(VOCAB_CAP).unwrap();
    let packable: Vec<usize> =
        exs.iter().map(|e| e.len()).filter(|&l| l <= S).collect();
    let padded_tokens: usize = packable.iter().sum();
    let stream = BatchStream::new(exs, PackingStrategy::Bfd, B, S, TailPolicy::Pad);

    println!(
        "bins: {} oversized: {} planned: {} batches: {} padded_tokens: {padded_tokens}",
        stream.n_bins(),
        stream.oversized_dropped(),
        stream.planned_tokens(),
        stream.n_batches(),
    );

    assert_eq!(stream.n_bins(), N_BINS);
    assert_eq!(stream.oversized_dropped(), N_OVERSIZED);
    assert_eq!(stream.planned_tokens(), PLANNED_TOKENS);
    assert_eq!(stream.n_batches(), BATCHES_PER_EPOCH);
    assert_eq!(packable.len(), PADDED_ROWS);
    assert_eq!(padded_tokens, PADDED_TOKENS);
    // 28 bins divide evenly into 7 batches of 4 — no padded tail
    assert!(!stream.tail_padded());

    // density / padding recovery exactly as Session::run derives them
    let density = PLANNED_TOKENS as f64 / (BATCHES_PER_EPOCH * B * S) as f64;
    let waste_padded = 1.0 - PADDED_TOKENS as f64 / (PADDED_ROWS * S) as f64;
    let waste_packed = 1.0 - PLANNED_TOKENS as f64 / (N_BINS * S) as f64;
    let recovery = (waste_padded - waste_packed) / waste_padded;
    println!("density: {density:.6} recovery: {recovery:.6}");
    assert!((density - 0.830915).abs() < 1e-4, "density {density}");
    assert!((recovery - 0.544490).abs() < 1e-4, "recovery {recovery}");
    assert!(recovery > 0.0, "the sample corpus must show real padding recovery");
}
