//! The no-materialization assertion for the fast CPU backend, in its own
//! test binary: `scratch::peak_elems()` is a process-global counter, so
//! isolating this file guarantees no other concurrently running test can
//! allocate through the fast path between `reset_peak` and the assertion
//! (integration-test files each get their own process).

use chronicals::backend::cpu::ModelDims;
use chronicals::backend::cpu_fast::{scratch, FastCpuBackend};
use chronicals::backend::Backend;
use chronicals::harness;

/// Run a full fast train step on a geometry where `[B, Hq, S, S]` and
/// `[T, V]` are large, and check the peak single f32 allocation recorded
/// by the fast backend's scratch accounting stays at the O(T·d_ff)
/// activation scale — far below either forbidden buffer.
#[test]
fn fast_path_never_materializes_probs_or_logits() {
    let dims =
        ModelDims { vocab: 256, d_model: 32, n_layers: 2, n_heads: 4, n_kv_heads: 2, d_ff: 64 };
    let (batch, seq) = (4usize, 128usize);
    let t = batch * seq;
    let bhss = batch * dims.n_heads * seq * seq; // 262144: the attention tensor
    let tv = t * dims.vocab; // 131072: the logits tensor
    let activation_ceiling = t * dims.d_ff.max(dims.d_model); // 32768: largest legit buffer

    let fast = FastCpuBackend::custom(dims, batch, seq, 2);
    let exe = "train_step_chronicals";
    let spec = fast.manifest().get(exe).unwrap().clone();
    let (_tok, exs) = harness::build_corpus(384, 5, spec.model_config.vocab, 96);
    let batches = harness::make_batches(fast.manifest(), exe, &exs, true).unwrap();
    let mut state = fast.init_state("init_chronicals", 5).unwrap();
    let ub = fast.upload_batch(exe, &batches[0]).unwrap();

    scratch::reset_peak();
    let out = fast.train_step(exe, &mut state, &ub, 1, 1e-3, 1e-3).unwrap();
    assert!(out.grad_norm > 0.0, "step must actually train");
    let peak = scratch::peak_elems();
    assert!(peak > 0, "scratch accounting saw no allocations");
    assert!(
        peak <= activation_ceiling,
        "peak single allocation {peak} exceeds the activation ceiling {activation_ceiling}"
    );
    assert!(peak < bhss / 4, "peak {peak} is within 4x of the [B,Hq,S,S] tensor ({bhss})");
    assert!(peak < tv / 2, "peak {peak} is within 2x of the [T,V] tensor ({tv})");
}
