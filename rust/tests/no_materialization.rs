//! Allocation-accounting assertions for the fast CPU backend.
//!
//! The counters live on the backend's own scratch arena
//! (`FastCpuBackend::exec().arena()`), not in a process-global — so these
//! tests cannot race against other tests that drive a fast backend
//! concurrently (the flake mode the old global counter admitted).
//!
//! Two contracts are pinned here:
//! * **No materialization** — the peak single *logical* buffer a train
//!   step leases stays at activation scale, far below `[B, Hq, S, S]` and
//!   `[T, V]`.
//! * **Warm arena** — after the cold first step populated the free list,
//!   steady-state train steps perform zero arena heap allocations, while
//!   the logical-size peak accounting keeps reflecting the largest buffer
//!   (leases record their logical size even when the physical buffer is
//!   recycled).

use chronicals::backend::cpu::model as cpu_model;
use chronicals::backend::cpu::ModelDims;
use chronicals::backend::cpu_fast::kernels::DEQ_ROWS;
use chronicals::backend::cpu_fast::FastCpuBackend;
use chronicals::backend::{Backend, DataParallel, DeviceState, FusedSlice, MemoryCfg};
use chronicals::batching::Batch;
use chronicals::harness;
use chronicals::quant::{BaseQuant, OptimStates};
use chronicals::runtime::HostTensor;
use std::sync::Arc;

fn dims() -> ModelDims {
    ModelDims { vocab: 256, d_model: 32, n_layers: 2, n_heads: 4, n_kv_heads: 2, d_ff: 64 }
}

/// Build a warmed-up (state, staged batch) pair on the accounting geometry.
fn setup(fast: &FastCpuBackend) -> (chronicals::backend::DeviceState, chronicals::backend::DeviceBatch) {
    setup_on(fast)
}

/// [`setup`] through the `Backend` trait — also serves the data-parallel
/// wrapper (its manifest/init/upload delegate to replica 0).
fn setup_on(be: &dyn Backend) -> (chronicals::backend::DeviceState, chronicals::backend::DeviceBatch) {
    let exe = "train_step_chronicals";
    let spec = be.manifest().get(exe).unwrap().clone();
    let (_tok, exs) = harness::build_corpus(384, 5, spec.model_config.vocab, 96);
    let batches = harness::make_batches(be.manifest(), exe, &exs, true).unwrap();
    let state = be.init_state("init_chronicals", 5).unwrap();
    let ub = be.upload_batch(exe, &batches[0]).unwrap();
    (state, ub)
}

/// A data-parallel wrapper over `workers` fast-CPU replicas on the
/// accounting geometry, with concrete handles kept for arena inspection.
fn dp_fast(workers: usize, batch: usize, seq: usize) -> (DataParallel, Vec<Arc<FastCpuBackend>>) {
    let replicas: Vec<Arc<FastCpuBackend>> =
        (0..workers).map(|_| Arc::new(FastCpuBackend::custom(dims(), batch, seq, 2))).collect();
    let dyns: Vec<Arc<dyn Backend>> =
        replicas.iter().map(|r| r.clone() as Arc<dyn Backend>).collect();
    (DataParallel::from_replicas(dyns).unwrap(), replicas)
}

/// Run a full fast train step on a geometry where `[B, Hq, S, S]` and
/// `[T, V]` are large, and check the peak single f32 lease recorded by the
/// backend's arena stays at the O(T·d_ff) activation scale — far below
/// either forbidden buffer.
#[test]
fn fast_path_never_materializes_probs_or_logits() {
    let dims = dims();
    let (batch, seq) = (4usize, 128usize);
    let t = batch * seq;
    let bhss = batch * dims.n_heads * seq * seq; // 262144: the attention tensor
    let tv = t * dims.vocab; // 131072: the logits tensor
    let activation_ceiling = t * dims.d_ff.max(dims.d_model); // 32768: largest legit buffer

    let fast = FastCpuBackend::custom(dims, batch, seq, 2);
    let (mut state, ub) = setup(&fast);

    fast.exec().arena().reset_peak();
    let out = fast.train_step("train_step_chronicals", &mut state, &ub, 1, 1e-3, 1e-3).unwrap();
    assert!(out.grad_norm > 0.0, "step must actually train");
    let peak = fast.exec().arena().peak_elems();
    assert!(peak > 0, "arena accounting saw no leases");
    assert!(
        peak <= activation_ceiling,
        "peak single lease {peak} exceeds the activation ceiling {activation_ceiling}"
    );
    assert!(peak < bhss / 4, "peak {peak} is within 4x of the [B,Hq,S,S] tensor ({bhss})");
    assert!(peak < tv / 2, "peak {peak} is within 2x of the [T,V] tensor ({tv})");
}

/// The forward-only eval pass (the held-out loss loop) is a subset of the
/// train step's forward: it must stay within the same activation-scale
/// lease ceiling — never materializing `[B, Hq, S, S]` or `[T, V]` — and a
/// warm arena serves it with zero new heap allocations, so periodic eval
/// adds no new peak buffers to a training run.
#[test]
fn eval_pass_adds_no_new_peak_buffers() {
    let dims = dims();
    let (batch, seq) = (4usize, 128usize);
    let t = batch * seq;
    let bhss = batch * dims.n_heads * seq * seq;
    let tv = t * dims.vocab;
    let activation_ceiling = t * dims.d_ff.max(dims.d_model);

    let fast = FastCpuBackend::custom(dims, batch, seq, 2);
    let exe = "train_step_chronicals";
    let spec = fast.manifest().get(exe).unwrap().clone();
    let (_tok, exs) = harness::build_corpus(384, 5, spec.model_config.vocab, 96);
    let batches = harness::make_batches(fast.manifest(), exe, &exs, true).unwrap();
    let mut state = fast.init_state("init_chronicals", 5).unwrap();
    let ub = fast.upload_batch(exe, &batches[0]).unwrap();

    // warm the arena with a full train step (forward + backward)
    fast.train_step(exe, &mut state, &ub, 1, 1e-3, 1e-3).unwrap();
    fast.exec().arena().reset_peak();
    fast.train_step(exe, &mut state, &ub, 2, 1e-3, 1e-3).unwrap();
    let train_peak = fast.exec().arena().peak_elems();
    let warm_allocs = fast.exec().arena().heap_allocs();

    fast.exec().arena().reset_peak();
    let loss = fast.eval_loss(exe, &state, &batches[0]).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "eval loss {loss}");
    let eval_peak = fast.exec().arena().peak_elems();
    assert!(eval_peak > 0, "arena accounting saw no eval leases");
    assert!(
        eval_peak <= train_peak,
        "eval peak {eval_peak} exceeds the train-step peak {train_peak}"
    );
    assert!(
        eval_peak <= activation_ceiling,
        "eval peak {eval_peak} exceeds the activation ceiling {activation_ceiling}"
    );
    assert!(eval_peak < bhss / 4, "eval peak {eval_peak} within 4x of [B,Hq,S,S] ({bhss})");
    assert!(eval_peak < tv / 2, "eval peak {eval_peak} within 2x of [T,V] ({tv})");
    assert_eq!(
        fast.exec().arena().heap_allocs(),
        warm_allocs,
        "a warm arena must serve the eval pass without new heap allocations"
    );
}

/// Steady-state steps lease everything from the warm free list: zero arena
/// heap allocations after step 1 — and the peak accounting still reports
/// the largest *logical* buffer even though every byte was recycled.
#[test]
fn warm_arena_steps_allocate_nothing_and_keep_peak_accounting() {
    let dims = dims();
    let (batch, seq) = (4usize, 128usize);
    let t = batch * seq;
    let largest_logical = t * dims.d_ff.max(dims.d_model);

    // pooled path (threads = 2): leases are taken on the dispatching
    // thread, so the warm-arena property must hold despite worker threads
    let fast = FastCpuBackend::custom(dims, batch, seq, 2);
    let (mut state, ub) = setup(&fast);

    fast.train_step("train_step_chronicals", &mut state, &ub, 1, 1e-3, 1e-3).unwrap();
    let cold = fast.exec().arena().heap_allocs();
    assert!(cold > 0, "the first step must populate the arena");

    for step in 2..=5u64 {
        let out = fast
            .train_step("train_step_chronicals", &mut state, &ub, step, 1e-3, 1e-3)
            .unwrap();
        assert!(out.grad_norm > 0.0);
    }
    assert_eq!(
        fast.exec().arena().heap_allocs(),
        cold,
        "steady-state train steps must perform zero arena heap allocations"
    );

    // warm-arena peak accounting: every lease records its logical size,
    // so a fully recycled step still reports the largest logical buffer
    fast.exec().arena().reset_peak();
    fast.train_step("train_step_chronicals", &mut state, &ub, 6, 1e-3, 1e-3).unwrap();
    assert_eq!(fast.exec().arena().heap_allocs(), cold, "measured step allocated");
    assert_eq!(
        fast.exec().arena().peak_elems(),
        largest_logical,
        "warm-step peak must reflect the largest logical buffer (T·d_ff)"
    );
}

/// The data-parallel reduction path shares its gradient arena across
/// steps: one heap allocation when the geometry is first seen, zero on
/// every steady-state step after it — the same warm-arena contract the
/// per-replica scratch arenas obey, now for the lanes + reduction tree.
#[test]
fn data_parallel_grad_arena_allocates_once() {
    let (batch, seq) = (4usize, 128usize);
    let (dp, _replicas) = dp_fast(2, batch, seq);
    let (mut state, ub) = setup_on(&dp);

    dp.train_step("train_step_chronicals", &mut state, &ub, 1, 1e-3, 1e-3).unwrap();
    assert_eq!(dp.grad_arena_heap_allocs(), 1, "first step sizes the arena exactly once");
    let lane_len = dp.flat_grad_len(&state).unwrap();
    assert_eq!(dp.grad_arena_elems(), batch * lane_len, "one flat lane per batch row");

    for step in 2..=5u64 {
        let out = dp
            .train_step("train_step_chronicals", &mut state, &ub, step, 1e-3, 1e-3)
            .unwrap();
        assert!(out.grad_norm > 0.0, "step {step} must train");
    }
    assert_eq!(
        dp.grad_arena_heap_allocs(),
        1,
        "steady-state shard→reduce→step must perform zero arena heap allocations"
    );
}

/// Peak accounting composes across the replica set: every replica that
/// ran rows reports a non-zero scratch peak at *row* scale (a `[1, S]`
/// forward/backward, far below the full-batch activation ceiling), the
/// aggregate is bounded by `workers × row-ceiling`, and warm replica
/// arenas serve their row shards without new heap allocations.
#[test]
fn data_parallel_peak_accounting_aggregates_per_replica_arenas() {
    let d = dims();
    let (batch, seq) = (4usize, 128usize);
    // a single-row shard's largest legitimate lease: S·max(d_ff, d_model)
    let row_ceiling = seq * d.d_ff.max(d.d_model);
    let (dp, replicas) = dp_fast(2, batch, seq);
    let (mut state, ub) = setup_on(&dp);

    // cold step: replicas size their scratch arenas for row-shard work
    dp.train_step("train_step_chronicals", &mut state, &ub, 1, 1e-3, 1e-3).unwrap();
    let warm_allocs: Vec<u64> =
        replicas.iter().map(|r| r.exec().arena().heap_allocs()).collect();
    for r in &replicas {
        r.exec().arena().reset_peak();
    }

    dp.train_step("train_step_chronicals", &mut state, &ub, 2, 1e-3, 1e-3).unwrap();
    let mut aggregate = 0usize;
    for (i, r) in replicas.iter().enumerate() {
        let peak = r.exec().arena().peak_elems();
        assert!(peak > 0, "replica {i} received rows but recorded no leases");
        assert!(
            peak <= row_ceiling,
            "replica {i} peak {peak} exceeds the row-shard ceiling {row_ceiling}"
        );
        aggregate += peak;
    }
    assert!(
        aggregate <= replicas.len() * row_ceiling,
        "aggregate replica peak {aggregate} exceeds workers × row ceiling"
    );
    for (i, r) in replicas.iter().enumerate() {
        assert_eq!(
            r.exec().arena().heap_allocs(),
            warm_allocs[i],
            "warm replica {i} must serve its row shard without new heap allocations"
        );
    }
    // and the shared gradient lanes are accounted separately, in full
    let lane_len = dp.flat_grad_len(&state).unwrap();
    assert_eq!(dp.grad_arena_elems(), batch * lane_len);
}

// ---------------------------------------------------------------------------
// Memory-tier pins (DESIGN.md §12): the three tiers must actually save the
// memory they claim, measured through the same arena accounting as the
// no-materialization contracts above.
// ---------------------------------------------------------------------------

/// Tier-2 pin: a quantized-base LoRA step never materializes a full FP32
/// copy of any frozen weight matrix. On a geometry where the MLP weights
/// are 262144 elements each, the per-tile dequant contract bounds the
/// largest single lease to `DEQ_ROWS · k` — the `w_down` tile at 65536
/// elements, 4x below the full matrix.
#[test]
fn quantized_base_never_leases_a_full_weight_matrix() {
    let d = ModelDims { vocab: 64, d_model: 256, n_layers: 2, n_heads: 4, n_kv_heads: 2, d_ff: 1024 };
    let (batch, seq) = (1usize, 16usize);
    let full_matrix = d.d_model * d.d_ff; // 262144: w_gate/w_up/w_down dense
    let tile_ceiling = DEQ_ROWS * d.d_ff; // 65536: the largest dequant tile

    let fast = FastCpuBackend::custom(d, batch, seq, 2);
    let exe = "train_step_lora";
    let spec = fast.manifest().get(exe).unwrap().clone();
    let (_tok, exs) = harness::build_corpus(64, 5, spec.model_config.vocab, 12);
    let batches = harness::make_batches(fast.manifest(), exe, &exs, true).unwrap();
    let mut state = fast.init_state("init_lora", 5).unwrap();
    let dense_bytes = match &state {
        DeviceState::Cpu(s) => cpu_model::base_weight_bytes(s),
        #[allow(unreachable_patterns)]
        _ => panic!("fast backend must produce DeviceState::Cpu"),
    };
    fast.configure_memory(
        &mut state,
        &MemoryCfg { base_quant: Some(BaseQuant::Int8), ..MemoryCfg::default() },
    )
    .unwrap();
    let ub = fast.upload_batch(exe, &batches[0]).unwrap();

    fast.exec().arena().reset_peak();
    let out = fast.train_step(exe, &mut state, &ub, 1, 1e-3, 1e-3).unwrap();
    assert!(out.grad_norm > 0.0, "quantized-base step must train");
    let peak = fast.exec().arena().peak_elems();
    assert!(peak > 0, "arena accounting saw no leases");
    assert!(
        peak <= tile_ceiling,
        "peak single lease {peak} exceeds the DEQ_ROWS·k tile ceiling {tile_ceiling}"
    );
    assert!(
        peak < full_matrix / 2,
        "peak {peak} is within 2x of a full dequantized weight matrix ({full_matrix})"
    );
    // and the quantized representation actually shrank the resident weights
    // (w_head and the norm vectors legitimately stay dense, so the whole-base
    // ratio lands near 3.7x rather than the per-matrix ~3.76x)
    let qbytes = match &state {
        DeviceState::Cpu(s) => cpu_model::base_weight_bytes(s),
        #[allow(unreachable_patterns)]
        _ => panic!("fast backend must produce DeviceState::Cpu"),
    };
    assert!(
        dense_bytes as f64 / qbytes as f64 >= 3.0,
        "quantized base {qbytes} B is not ≥3x below the dense base {dense_bytes} B"
    );
}

/// Tier-1 pin: switching the AdamW slots to int8 shrinks the optimizer
/// state bytes by at least 3.5x (int8 payload + per-block scale/comp
/// overhead vs 4 bytes/element fp32).
#[test]
fn int8_optimizer_states_shrink_at_least_3_5x() {
    let fast = FastCpuBackend::custom(dims(), 4, 128, 1);
    let bytes_for = |codec: OptimStates| -> usize {
        let mut state = fast.init_state("init_chronicals", 7).unwrap();
        fast.configure_memory(&mut state, &MemoryCfg { optim_states: codec, ..MemoryCfg::default() })
            .unwrap();
        match &state {
            DeviceState::Cpu(s) => cpu_model::optim_state_bytes(s),
            #[allow(unreachable_patterns)]
            _ => panic!("fast backend must produce DeviceState::Cpu"),
        }
    };
    let fp32 = bytes_for(OptimStates::Fp32);
    let int8 = bytes_for(OptimStates::Int8);
    assert!(fp32 > 0 && int8 > 0, "fp32 {fp32} B, int8 {int8} B");
    let ratio = fp32 as f64 / int8 as f64;
    assert!(
        ratio >= 3.5,
        "int8 optimizer states must shrink ≥3.5x: fp32 {fp32} B / int8 {int8} B = {ratio:.2}x"
    );
}

/// Tier-3 pin: with `--ckpt-segments 2` the warm-arena *concurrent* peak
/// (every live lease summed) drops below the no-checkpoint step's peak —
/// the interior activation caches are genuinely not held across the
/// forward — and steady-state checkpointed steps still perform zero arena
/// heap allocations.
#[test]
fn checkpointed_steps_lower_concurrent_peak_with_warm_arena() {
    let d = ModelDims { vocab: 64, d_model: 32, n_layers: 4, n_heads: 4, n_kv_heads: 2, d_ff: 64 };
    let (batch, seq) = (4usize, 64usize);
    let exe = "train_step_chronicals";

    let peak_for = |segs: usize| -> (usize, usize) {
        let fast = FastCpuBackend::custom(d, batch, seq, 2);
        let spec = fast.manifest().get(exe).unwrap().clone();
        let (_tok, exs) = harness::build_corpus(256, 5, spec.model_config.vocab, 48);
        let batches = harness::make_batches(fast.manifest(), exe, &exs, true).unwrap();
        let mut state = fast.init_state("init_chronicals", 5).unwrap();
        if segs > 0 {
            fast.configure_memory(
                &mut state,
                &MemoryCfg { ckpt_segments: segs, ..MemoryCfg::default() },
            )
            .unwrap();
        }
        let ub = fast.upload_batch(exe, &batches[0]).unwrap();
        // warm the arena, then measure a steady-state step
        fast.train_step(exe, &mut state, &ub, 1, 1e-3, 1e-3).unwrap();
        let warm_allocs = fast.exec().arena().heap_allocs();
        fast.exec().arena().reset_peak();
        let out = fast.train_step(exe, &mut state, &ub, 2, 1e-3, 1e-3).unwrap();
        assert!(out.grad_norm > 0.0, "segs={segs}: step must train");
        assert_eq!(
            fast.exec().arena().heap_allocs(),
            warm_allocs,
            "segs={segs}: a warm arena must serve the step without new heap allocations"
        );
        (fast.exec().arena().peak_total_elems(), warm_allocs)
    };

    let (full_peak, _) = peak_for(0);
    let (ckpt_peak, _) = peak_for(2);
    assert!(full_peak > 0 && ckpt_peak > 0);
    assert!(
        ckpt_peak < full_peak,
        "ckpt-segments=2 concurrent peak {ckpt_peak} must drop below the \
         no-checkpoint peak {full_peak}"
    );
}

/// Row-concatenate two same-geometry batches into one `[B_a + B_b, S]`
/// fused-round batch (what the serve scheduler builds under `--fuse intra`).
fn concat(a: &Batch, b: &Batch) -> Batch {
    assert_eq!(a.seq, b.seq);
    let cat = |x: &HostTensor, y: &HostTensor| {
        let mut v = x.as_i32().unwrap().to_vec();
        v.extend_from_slice(y.as_i32().unwrap());
        HostTensor::i32(v, vec![a.batch + b.batch, a.seq])
    };
    Batch {
        tokens: cat(&a.tokens, &b.tokens),
        targets: cat(&a.targets, &b.targets),
        seg_ids: cat(&a.seg_ids, &b.seg_ids),
        pos_ids: cat(&a.pos_ids, &b.pos_ids),
        real_tokens: a.real_tokens + b.real_tokens,
        real_targets: a.real_targets + b.real_targets,
        batch: a.batch + b.batch,
        seq: a.seq,
    }
}

/// The intra-step fused round performs exactly one shared base
/// forward/backward over the concatenated `[B_total, S]` batch: its peak
/// single lease is the concat-scale activation buffer (`T_total·d_ff`),
/// never above the *sum* of the tenants' standalone peaks — i.e. fusing
/// does not secretly materialize per-tenant copies of the base pass — and
/// a warm arena serves the whole fused step with zero heap allocations.
#[test]
fn intra_fused_step_peaks_at_concat_scale_and_reuses_the_warm_arena() {
    let d = dims();
    let (batch, seq) = (4usize, 128usize);
    let fused_rows = 2 * batch;
    let fused_t = fused_rows * seq;
    let fused_ceiling = fused_t * d.d_ff.max(d.d_model); // 65536: concat activations
    let bhss = fused_rows * d.n_heads * seq * seq; // the fused attention tensor
    let tv = fused_t * d.vocab; // the fused logits tensor

    let fast = FastCpuBackend::custom(d, batch, seq, 2);
    let exe = "train_step_lora";
    let spec = fast.manifest().get(exe).unwrap().clone();
    let (_tok, exs) = harness::build_corpus(384, 5, spec.model_config.vocab, 96);
    let batches = harness::make_batches(fast.manifest(), exe, &exs, true).unwrap();
    assert!(batches.len() >= 2, "need two tenant batches, got {}", batches.len());

    // per-tenant reference: one ordinary LoRA step at the [B, S] geometry
    let mut state = fast.init_state("init_lora", 5).unwrap();
    let ub = fast.upload_batch(exe, &batches[0]).unwrap();
    fast.exec().arena().reset_peak();
    fast.train_step(exe, &mut state, &ub, 1, 1e-3, 1e-3).unwrap();
    let tenant_peak = fast.exec().arena().peak_elems();
    assert!(tenant_peak > 0, "arena accounting saw no tenant leases");

    // the fused round: two tenants, one concatenated [2B, S] batch
    let mut adapters =
        vec![fast.init_adapter(exe, 21).unwrap(), fast.init_adapter(exe, 22).unwrap()];
    let fused_batch = concat(&batches[0], &batches[1]);
    let slices = [
        FusedSlice { row_start: 0, rows: batch, step: 1, lr: 1e-3, lr_b: 1e-3 },
        FusedSlice { row_start: batch, rows: batch, step: 1, lr: 1e-3, lr_b: 2e-3 },
    ];
    fast.exec().arena().reset_peak();
    let out = fast.fused_step(exe, &state, &mut adapters, &fused_batch, &slices).unwrap();
    assert_eq!(out.tenants.len(), 2);
    assert!(out.tenants.iter().all(|t| t.grad_norm > 0.0), "fused step must train: {out:?}");
    let fused_peak = fast.exec().arena().peak_elems();
    let cold = fast.exec().arena().heap_allocs();
    assert_eq!(
        fused_peak, fused_ceiling,
        "fused peak must be exactly the concat-scale activation buffer"
    );
    assert!(
        fused_peak <= 2 * tenant_peak,
        "fused peak {fused_peak} exceeds the sum of per-tenant peaks ({tenant_peak} each)"
    );
    assert!(fused_peak < bhss / 4, "fused peak {fused_peak} within 4x of [B,Hq,S,S] ({bhss})");
    assert!(fused_peak < tv / 2, "fused peak {fused_peak} within 2x of [T,V] ({tv})");

    // warm fused step: zero new heap allocations — structurally one shared
    // base pass with no hidden per-tenant buffer duplication
    let slices2 = [
        FusedSlice { row_start: 0, rows: batch, step: 2, lr: 1e-3, lr_b: 1e-3 },
        FusedSlice { row_start: batch, rows: batch, step: 2, lr: 1e-3, lr_b: 2e-3 },
    ];
    fast.fused_step(exe, &state, &mut adapters, &fused_batch, &slices2).unwrap();
    assert_eq!(
        fast.exec().arena().heap_allocs(),
        cold,
        "a warm arena must serve the fused step without new heap allocations"
    );
}
