//! End-to-end integration over the real AOT artifacts: runtime loads every
//! executable, training steps reduce loss, the grad-norm verifier separates
//! healthy from broken configs, eval matches, checkpoints round-trip.
//!
//! Requires `make artifacts`. Tests return early (skip) when the artifacts
//! directory is missing so `cargo test` stays green on a fresh clone.

use chronicals::batching::packed_batches;
use chronicals::checkpoint;
use chronicals::config::RunConfig;
use chronicals::coordinator::Trainer;
use chronicals::harness;
use chronicals::optim::LrSchedule;
use chronicals::runtime::{HostTensor, Runtime, TrainState};
use std::rc::Rc;

fn runtime() -> Option<Rc<Runtime>> {
    Runtime::new("artifacts").ok().map(Rc::new)
}

#[test]
fn manifest_lists_all_variants() {
    let Some(rt) = runtime() else { return };
    for name in [
        "train_step_ablate_naive",
        "train_step_ablate_flash",
        "train_step_ablate_compiled",
        "train_step_ablate_liger",
        "train_step_chronicals",
        "train_step_lora",
        "train_step_lora_broken",
        "train_step_opt_sf",
        "train_step_opt_muon",
        "train_step_opt_atan2",
        "train_step_dora",
        "train_step_chronicals_pallas",
        "train_step_e2e",
        "init_chronicals",
        "init_lora",
        "eval_chronicals",
    ] {
        assert!(rt.manifest.get(name).is_ok(), "missing {name}");
    }
}

#[test]
fn full_ft_loss_decreases_over_10_steps() {
    let Some(rt) = runtime() else { return };
    let cfg = RunConfig {
        executable: "train_step_chronicals".into(),
        steps: 10,
        warmup_steps: 0,
        lr: 5e-3,
        packed: true,
        corpus_examples: 256,
        ..RunConfig::default()
    };
    let s = harness::run_variant(&rt, &cfg).unwrap();
    assert!(s.last_loss.is_finite());
    assert!(
        s.last_loss < s.first_loss,
        "loss {} -> {}",
        s.first_loss,
        s.last_loss
    );
    assert!(s.verification.is_training, "{:?}", s.verification.failures);
}

#[test]
fn lora_plus_beats_lora_at_equal_steps() {
    // paper Fig. 17 at integration level
    let Some(rt) = runtime() else { return };
    let run = |ratio: f64| {
        let cfg = RunConfig {
            executable: "train_step_lora".into(),
            steps: 12,
            warmup_steps: 0,
            lr: 1e-3,
            lora_plus_ratio: ratio,
            packed: true,
            corpus_examples: 256,
            ..RunConfig::default()
        };
        harness::run_variant(&rt, &cfg).unwrap().last_loss
    };
    let lora = run(1.0);
    let lora_plus = run(16.0);
    assert!(
        lora_plus < lora,
        "LoRA+ {lora_plus} should beat LoRA {lora}"
    );
}

#[test]
fn broken_variant_flagged_by_verifier() {
    let Some(rt) = runtime() else { return };
    let cfg = RunConfig {
        executable: "train_step_lora_broken".into(),
        steps: 5,
        warmup_steps: 0,
        packed: true,
        corpus_examples: 128,
        ..RunConfig::default()
    };
    let s = harness::run_variant(&rt, &cfg).unwrap();
    assert!(!s.verification.is_training);
    assert_eq!(s.verification.zero_grad_steps, 5);
}

#[test]
fn variant_losses_agree_on_first_step() {
    // naive / flash / compiled / liger / chronicals are the same math:
    // identical init + identical batch => near-identical first-step loss.
    let Some(rt) = runtime() else { return };
    let mut losses = Vec::new();
    for exe in [
        "train_step_ablate_naive",
        "train_step_ablate_flash",
        "train_step_ablate_compiled",
        "train_step_ablate_liger",
        "train_step_chronicals",
    ] {
        let cfg = RunConfig {
            executable: exe.into(),
            steps: 1,
            warmup_steps: 0,
            packed: false,
            corpus_examples: 128,
            seed: 7,
            ..RunConfig::default()
        };
        let s = harness::run_variant(&rt, &cfg).unwrap();
        losses.push(s.first_loss);
    }
    for w in losses.windows(2) {
        assert!(
            (w[0] - w[1]).abs() / w[0].abs() < 2e-3,
            "variant losses diverge: {losses:?}"
        );
    }
}

#[test]
fn pallas_composition_variant_trains() {
    // every L1 Pallas kernel composed into one executable
    let Some(rt) = runtime() else { return };
    let cfg = RunConfig {
        executable: "train_step_chronicals_pallas".into(),
        steps: 3,
        warmup_steps: 0,
        lr: 5e-3,
        packed: true,
        corpus_examples: 64,
        ..RunConfig::default()
    };
    let s = harness::run_variant(&rt, &cfg).unwrap();
    assert!(s.last_loss.is_finite());
    assert!(s.verification.min_grad_norm > 0.0);
}

#[test]
fn optimizer_variants_train() {
    let Some(rt) = runtime() else { return };
    for exe in [
        "train_step_opt_sf",
        "train_step_opt_muon",
        "train_step_opt_atan2",
        "train_step_dora",
    ] {
        // per-optimizer lr: muon's orthogonalized update has unit scale per
        // element (×√n), so it needs a far smaller lr than AdamW here
        let lr = match exe {
            e if e.ends_with("sf") => 2e-3,
            e if e.ends_with("muon") => 2e-4,
            _ => 5e-3,
        };
        let cfg = RunConfig {
            executable: exe.into(),
            steps: 6,
            warmup_steps: 0,
            lr,
            packed: true,
            corpus_examples: 128,
            ..RunConfig::default()
        };
        let s = harness::run_variant(&rt, &cfg).unwrap();
        assert!(s.last_loss.is_finite(), "{exe}");
        assert!(
            s.last_loss < s.first_loss,
            "{exe}: {} -> {}",
            s.first_loss,
            s.last_loss
        );
    }
}

#[test]
fn eval_matches_between_steps() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.get("train_step_chronicals").unwrap().clone();
    let vocab = spec.model_config.vocab;
    let (_tok, exs) = harness::build_corpus(128, 3, vocab, 512);
    let batches = packed_batches(&exs, spec.batch, spec.seq);
    let init = harness::resolve_init(&rt, "train_step_chronicals", "init_chronicals").unwrap();
    let state = TrainState::init(&rt, &init, 3).unwrap();
    let mut trainer = Trainer::new(
        rt.clone(),
        "train_step_chronicals",
        state,
        LrSchedule::constant(1e-3, 1.0),
        0,
    )
    .unwrap();
    let eval0 = trainer.eval("eval_chronicals", &batches[0]).unwrap();
    let rec = trainer.step(&batches[0]).unwrap();
    // eval (pre-step params) must equal the training loss on the same batch
    assert!(
        (eval0 - rec.loss).abs() / rec.loss.abs() < 1e-4,
        "eval {eval0} vs step loss {}",
        rec.loss
    );
    // after one step, eval on the same batch must improve
    let eval1 = trainer.eval("eval_chronicals", &batches[0]).unwrap();
    assert!(eval1 < eval0);
}

#[test]
fn checkpoint_roundtrip_from_device_state() {
    let Some(rt) = runtime() else { return };
    let init = harness::resolve_init(&rt, "train_step_chronicals", "init_chronicals").unwrap();
    let state = TrainState::init(&rt, &init, 11).unwrap();
    let params = state.params_to_host().unwrap();
    let tensors: Vec<HostTensor> = params
        .iter()
        .map(|l| HostTensor::from_literal(l).unwrap())
        .collect();
    let path = std::env::temp_dir().join("chronicals_integration.ckpt");
    checkpoint::save(&path, &tensors, checkpoint::Codec::F32).unwrap();
    let back = checkpoint::load(&path).unwrap();
    assert_eq!(tensors.len(), back.len());
    for (a, b) in tensors.iter().zip(&back) {
        assert_eq!(a, b);
    }
}

#[test]
fn packed_throughput_beats_padded() {
    // the Fig. 18 / Table 4 "+packing" effect measured end to end:
    // same executable, packed batches carry more real tokens per step.
    let Some(rt) = runtime() else { return };
    let run = |packed: bool| {
        let cfg = RunConfig {
            executable: "train_step_chronicals".into(),
            steps: 8,
            warmup_steps: 2,
            packed,
            corpus_examples: 512,
            ..RunConfig::default()
        };
        harness::run_variant(&rt, &cfg).unwrap().tokens_per_sec
    };
    let padded = run(false);
    let packed = run(true);
    assert!(
        packed > padded,
        "packed {packed} should beat padded {padded} tok/s"
    );
}

#[test]
fn kernel_microbenches_execute() {
    let Some(rt) = runtime() else { return };
    let rows = harness::kernel_microbench(&rt, 3).unwrap();
    assert_eq!(rows.len(), 7);
    for (name, fused, naive) in rows {
        assert!(fused > 0.0 && naive > 0.0, "{name}");
    }
}
