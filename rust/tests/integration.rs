//! End-to-end integration over the full train loop: corpus → tokenize →
//! BFD-pack → batch → step → verify → checkpoint.
//!
//! The CPU-backend tests run unconditionally — no artifacts, no network, no
//! native deps — so a missing `artifacts/` directory can never turn this
//! suite vacuously green. The PJRT variants (bottom module) are additionally
//! exercised when the crate is built with `--features pjrt` against real
//! artifacts; they skip *loudly* when artifacts are absent.

use chronicals::backend::cpu::CpuBackend;
use chronicals::backend::{Backend, MemoryCfg};
use chronicals::checkpoint;
use chronicals::quant::OptimStates;
use chronicals::config::RunConfig;
use chronicals::coordinator::Trainer;
use chronicals::harness;
use chronicals::optim::LrSchedule;
use std::sync::Arc;

fn cpu() -> Arc<dyn Backend> {
    Arc::new(CpuBackend::new())
}

/// A config sized so every example fits a 64-token packing bin and a 12-step
/// run takes well under a second.
fn cpu_cfg(exe: &str) -> RunConfig {
    RunConfig {
        executable: exe.into(),
        steps: 12,
        warmup_steps: 0,
        lr: 5e-3,
        packed: true,
        corpus_examples: 192,
        max_seq: 48,
        ..RunConfig::default()
    }
}

#[test]
fn cpu_manifest_lists_reference_variants() {
    let be = cpu();
    for name in [
        "train_step_chronicals",
        "train_step_ablate_naive",
        "train_step_ablate_flash",
        "train_step_ablate_compiled",
        "train_step_ablate_liger",
        "train_step_lora",
        "train_step_lora_naive",
        "train_step_lora_broken",
        "init_chronicals",
        "init_lora",
        "eval_chronicals",
    ] {
        assert!(be.manifest().get(name).is_ok(), "missing {name}");
    }
}

#[test]
fn full_ft_loss_decreases_over_12_steps() {
    let be = cpu();
    let s = harness::run_variant(&be, &cpu_cfg("train_step_chronicals")).unwrap();
    assert_eq!(s.steps, 12);
    assert!(s.last_loss.is_finite());
    assert!(
        s.last_loss < s.first_loss,
        "loss {} -> {}",
        s.first_loss,
        s.last_loss
    );
    assert!(s.verification.is_training, "{:?}", s.verification.failures);
    assert!(s.verification.min_grad_norm > 0.0);
    assert_eq!(s.param_count, s.trainable_param_count); // full FT trains all
}

#[test]
fn lora_trains_and_lora_plus_ratio_changes_the_run() {
    let be = cpu();
    let run = |ratio: f64| {
        let cfg = RunConfig {
            lr: 2e-3,
            lora_plus_ratio: ratio,
            ..cpu_cfg("train_step_lora")
        };
        harness::run_variant(&be, &cfg).unwrap()
    };
    let lora = run(1.0);
    let lora_plus = run(16.0);
    assert!(lora.verification.is_training, "{:?}", lora.verification.failures);
    assert!(lora_plus.verification.is_training);
    assert!(lora.last_loss < lora.first_loss);
    assert!(lora_plus.last_loss < lora_plus.first_loss);
    // λ=16 must actually reach the B-matrix update path: identical inits and
    // batches, different λ ⇒ different trajectories
    assert_ne!(lora.last_loss.to_bits(), lora_plus.last_loss.to_bits());
    // adapters only: trainable is a strict subset of the params
    assert!(lora.trainable_param_count < lora.param_count);
}

#[test]
fn broken_config_flagged_by_verifier() {
    let be = cpu();
    let cfg = RunConfig { steps: 10, ..cpu_cfg("train_step_lora_broken") };
    let s = harness::run_variant(&be, &cfg).unwrap();
    assert!(!s.verification.is_training);
    assert_eq!(s.verification.zero_grad_steps, 10);
    assert_eq!(s.verification.max_grad_norm, 0.0);
    assert!(!s.verification.loss_changed, "broken config must not learn");
    assert!(
        s.verification
            .failures
            .iter()
            .any(|f| f.contains("NOT training")),
        "{:?}",
        s.verification.failures
    );
}

#[test]
fn ablation_aliases_share_the_reference_math() {
    // identical seed + batches ⇒ identical first-step loss across the
    // full-family variants (they are semantic aliases on this backend)
    let be = cpu();
    let mut losses = Vec::new();
    for exe in [
        "train_step_ablate_naive",
        "train_step_ablate_flash",
        "train_step_chronicals",
    ] {
        let cfg = RunConfig { steps: 1, seed: 7, ..cpu_cfg(exe) };
        losses.push(harness::run_variant(&be, &cfg).unwrap().first_loss);
    }
    assert_eq!(losses[0].to_bits(), losses[1].to_bits());
    assert_eq!(losses[1].to_bits(), losses[2].to_bits());
}

#[test]
fn padded_and_packed_paths_both_train() {
    let be = cpu();
    for packed in [false, true] {
        let cfg = RunConfig { packed, ..cpu_cfg("train_step_chronicals") };
        let s = harness::run_variant(&be, &cfg).unwrap();
        assert!(
            s.last_loss < s.first_loss,
            "packed={packed}: {} -> {}",
            s.first_loss,
            s.last_loss
        );
    }
}

#[test]
fn packed_batches_carry_more_real_tokens() {
    // the Fig. 18 / Table 4 "+packing" effect at the batch level: same
    // corpus, same [B, S] geometry, higher density packed
    let be = cpu();
    let spec = be.manifest().get("train_step_chronicals").unwrap().clone();
    // 24-token examples in 64-token rows: padded wastes ≥ 60%, BFD packs ≥ 2
    // segments per row, so the gap is structural, not distribution luck
    let (_tok, exs) = harness::build_corpus(192, 42, spec.model_config.vocab, 24);
    let padded = harness::make_batches(be.manifest(), "train_step_chronicals", &exs, false).unwrap();
    let packed = harness::make_batches(be.manifest(), "train_step_chronicals", &exs, true).unwrap();
    let pd: f64 = padded.iter().map(|b| b.density()).sum::<f64>() / padded.len() as f64;
    let kd: f64 = packed.iter().map(|b| b.density()).sum::<f64>() / packed.len() as f64;
    assert!(kd > pd, "packed density {kd} <= padded {pd}");
}

#[test]
fn eval_matches_train_loss_and_improves_after_step() {
    let be = cpu();
    let spec = be.manifest().get("train_step_chronicals").unwrap().clone();
    let (_tok, exs) = harness::build_corpus(96, 3, spec.model_config.vocab, 48);
    let batches = harness::make_batches(be.manifest(), "train_step_chronicals", &exs, true).unwrap();
    let state = be.init_state("init_chronicals", 3).unwrap();
    let mut trainer = Trainer::new(
        be.clone(),
        "train_step_chronicals",
        state,
        LrSchedule::constant(5e-3, 1.0),
        0,
    )
    .unwrap();
    let eval0 = trainer.eval("eval_chronicals", &batches[0]).unwrap();
    let rec = trainer.step(&batches[0]).unwrap();
    // eval (pre-step params) is the same math as the training loss: exact
    assert_eq!(eval0.to_bits(), rec.loss.to_bits());
    // after one step, eval on the same batch must improve
    let eval1 = trainer.eval("eval_chronicals", &batches[0]).unwrap();
    assert!(eval1 < eval0, "{eval1} vs {eval0}");
}

#[test]
fn staged_batch_is_reusable_across_steps() {
    let be = cpu();
    let spec = be.manifest().get("train_step_chronicals").unwrap().clone();
    let (_tok, exs) = harness::build_corpus(96, 1, spec.model_config.vocab, 48);
    let batches = harness::make_batches(be.manifest(), "train_step_chronicals", &exs, true).unwrap();
    let state = be.init_state("init_chronicals", 1).unwrap();
    let mut trainer = Trainer::new(
        be.clone(),
        "train_step_chronicals",
        state,
        LrSchedule::constant(5e-3, 1.0),
        0,
    )
    .unwrap();
    let ub = trainer.upload_batch(&batches[0]).unwrap();
    let r1 = trainer.step_uploaded(&ub).unwrap();
    assert!(r1.loss.is_finite() && r1.grad_norm > 0.0);
    let r2 = trainer.step_uploaded(&ub).unwrap();
    assert!(r2.loss < r1.loss, "{} -> {}", r1.loss, r2.loss);
    // un-staged single step agrees with the staged path
    let r3 = trainer.step(&batches[0]).unwrap();
    assert!(r3.loss < r2.loss);
}

#[test]
fn checkpoint_roundtrip_restores_exact_params_and_loss() {
    let be = cpu();
    let spec = be.manifest().get("train_step_chronicals").unwrap().clone();
    let (_tok, exs) = harness::build_corpus(96, 11, spec.model_config.vocab, 48);
    let batches = harness::make_batches(be.manifest(), "train_step_chronicals", &exs, true).unwrap();

    // train 10 steps, checkpoint
    let state = be.init_state("init_chronicals", 11).unwrap();
    let mut trainer = Trainer::new(
        be.clone(),
        "train_step_chronicals",
        state,
        LrSchedule::constant(5e-3, 1.0),
        0,
    )
    .unwrap();
    for _ in 0..10 {
        trainer.step(&batches[0]).unwrap();
    }
    let path = std::env::temp_dir().join("chronicals_cpu_integration.ckpt");
    trainer.save_checkpoint(&path, checkpoint::Codec::F32).unwrap();
    let eval_trained = trainer.eval("eval_chronicals", &batches[0]).unwrap();
    let params_trained = trainer.params_to_host().unwrap();

    // restore into a *different* init (other seed): must become identical
    let state2 = be.init_state("init_chronicals", 999).unwrap();
    let mut restored = Trainer::new(
        be.clone(),
        "train_step_chronicals",
        state2,
        LrSchedule::constant(5e-3, 1.0),
        0,
    )
    .unwrap();
    assert_ne!(
        restored.eval("eval_chronicals", &batches[0]).unwrap().to_bits(),
        eval_trained.to_bits(),
        "different seeds should not coincide"
    );
    restored.load_checkpoint(&path).unwrap();
    assert_eq!(restored.params_to_host().unwrap(), params_trained);
    assert_eq!(
        restored.eval("eval_chronicals", &batches[0]).unwrap().to_bits(),
        eval_trained.to_bits()
    );
}

/// Build a LoRA trainer over the shared corpus with the given optimizer-state
/// codec (the memory tier is configured on the device state before the first
/// step, exactly as `Session::with_backend` does).
fn lora_trainer(be: &Arc<dyn Backend>, init_seed: i32, codec: OptimStates) -> Trainer {
    let mut state = be.init_state("init_lora", init_seed).unwrap();
    if codec != OptimStates::Fp32 {
        let mem = MemoryCfg { optim_states: codec, ..MemoryCfg::default() };
        be.configure_memory(&mut state, &mem).unwrap();
    }
    Trainer::new(be.clone(), "train_step_lora", state, LrSchedule::constant(2e-3, 1.0), 0)
        .unwrap()
}

#[test]
fn train_state_resume_equals_continuous_for_both_optim_codecs() {
    // The resume-equals-continuous golden (DESIGN.md §12): train k steps,
    // save the full train state (params + step counter + optimizer slots in
    // their native codec), reload into a fresh differently-seeded trainer
    // configured with the same codec, and run m more steps. The resumed tail
    // must match the continuous run bit for bit — for fp32 moments AND for
    // int8 slots, whose raw bytes round-trip through the CHKS1 format.
    let be = cpu();
    let spec = be.manifest().get("train_step_lora").unwrap().clone();
    let (_tok, exs) = harness::build_corpus(96, 7, spec.model_config.vocab, 48);
    let batches = harness::make_batches(be.manifest(), "train_step_lora", &exs, true).unwrap();
    for codec in [OptimStates::Fp32, OptimStates::Int8] {
        let path = std::env::temp_dir()
            .join(format!("chronicals_train_state_{}.ckpt", codec.name()));
        let mut cont = lora_trainer(&be, 7, codec);
        for i in 0..5 {
            cont.step(&batches[i % batches.len()]).unwrap();
        }
        assert_eq!(cont.current_step(), 5);
        cont.save_train_state(&path).unwrap();
        let tail = |t: &mut Trainer| -> Vec<(u64, u32, u32)> {
            (5..9)
                .map(|i| {
                    let r = t.step(&batches[i % batches.len()]).unwrap();
                    (r.step, r.loss.to_bits(), r.grad_norm.to_bits())
                })
                .collect()
        };
        let cont_tail = tail(&mut cont);

        // the other seed guarantees the reload does the work, not the init
        let mut resumed = lora_trainer(&be, 999, codec);
        resumed.load_train_state(&path).unwrap();
        assert_eq!(resumed.current_step(), 5, "{codec:?}: step counter not restored");
        let resumed_tail = tail(&mut resumed);
        assert_eq!(
            cont_tail, resumed_tail,
            "{codec:?}: resumed run diverged from the continuous run"
        );
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn train_state_codec_migration_rejected_with_real_error() {
    // fp32↔int8 migration of live moments is rejected, never silently
    // rounded: a snapshot saved under one codec must not load into a state
    // configured with the other — in either direction.
    let be = cpu();
    let spec = be.manifest().get("train_step_lora").unwrap().clone();
    let (_tok, exs) = harness::build_corpus(96, 7, spec.model_config.vocab, 48);
    let batches = harness::make_batches(be.manifest(), "train_step_lora", &exs, true).unwrap();
    for (save_codec, load_codec) in
        [(OptimStates::Int8, OptimStates::Fp32), (OptimStates::Fp32, OptimStates::Int8)]
    {
        let path = std::env::temp_dir().join(format!(
            "chronicals_train_state_migrate_{}_{}.ckpt",
            save_codec.name(),
            load_codec.name()
        ));
        let mut t = lora_trainer(&be, 7, save_codec);
        for i in 0..2 {
            t.step(&batches[i]).unwrap();
        }
        t.save_train_state(&path).unwrap();

        let mut other = lora_trainer(&be, 7, load_codec);
        let err = format!("{:#}", other.load_train_state(&path).unwrap_err());
        assert!(
            err.contains("optimizer-state codec mismatch"),
            "{save_codec:?}->{load_codec:?}: got '{err}'"
        );
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn same_seed_runs_are_bitwise_identical() {
    // the determinism gate for future perf comparisons: the full
    // corpus→pack→train pipeline, run twice, must emit identical
    // StepRecord streams (loss, grad_norm, n_tokens — bit for bit)
    let run = || {
        let be = cpu();
        let spec = be.manifest().get("train_step_chronicals").unwrap().clone();
        let (_tok, exs) = harness::build_corpus(192, 42, spec.model_config.vocab, 48);
        let batches =
            harness::make_batches(be.manifest(), "train_step_chronicals", &exs, true).unwrap();
        let state = be.init_state("init_chronicals", 42).unwrap();
        let mut trainer = Trainer::new(
            be.clone(),
            "train_step_chronicals",
            state,
            LrSchedule::warmup_cosine(5e-3, 2, 12, 1.0),
            0,
        )
        .unwrap();
        assert!(batches.len() >= 12, "corpus too small for a 12-step epoch");
        trainer.run(batches.iter().cloned().take(12)).unwrap();
        trainer
            .records
            .iter()
            .map(|r| (r.step, r.loss.to_bits(), r.grad_norm.to_bits(), r.n_tokens.to_bits()))
            .collect::<Vec<_>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a.len(), 12);
    assert_eq!(a, b, "two same-seed runs diverged");
}

#[test]
fn verifier_separates_healthy_from_broken_at_equal_config() {
    // the paper's Fig. 10 contrast, end to end on one backend: identical
    // data and lr, only the broken flag differs
    let be = cpu();
    let healthy = harness::run_variant(&be, &cpu_cfg("train_step_lora")).unwrap();
    let broken = harness::run_variant(&be, &cpu_cfg("train_step_lora_broken")).unwrap();
    assert!(healthy.verification.is_training);
    assert!(!broken.verification.is_training);
    assert!(healthy.verification.min_grad_norm > 0.0);
    assert_eq!(broken.verification.max_grad_norm, 0.0);
    assert_eq!(healthy.first_loss.to_bits(), broken.first_loss.to_bits());
}

/// PJRT integration (requires `--features pjrt`, vendored xla-rs and `make
/// artifacts`). Skips loudly — never silently — when artifacts are missing.
#[cfg(feature = "pjrt")]
mod pjrt_integration {
    use super::*;
    use chronicals::backend::pjrt::PjrtBackend;

    fn pjrt() -> Option<Arc<dyn Backend>> {
        match PjrtBackend::new("artifacts") {
            Ok(be) => Some(Arc::new(be)),
            Err(e) => {
                eprintln!("SKIPPED pjrt integration (artifacts/runtime unavailable): {e:#}");
                None
            }
        }
    }

    #[test]
    fn manifest_lists_all_variants() {
        let Some(be) = pjrt() else { return };
        for name in [
            "train_step_ablate_naive",
            "train_step_ablate_flash",
            "train_step_ablate_compiled",
            "train_step_ablate_liger",
            "train_step_chronicals",
            "train_step_lora",
            "train_step_lora_broken",
            "train_step_opt_sf",
            "train_step_opt_muon",
            "train_step_opt_atan2",
            "train_step_dora",
            "train_step_chronicals_pallas",
            "train_step_e2e",
            "init_chronicals",
            "init_lora",
            "eval_chronicals",
        ] {
            assert!(be.manifest().get(name).is_ok(), "missing {name}");
        }
    }

    #[test]
    fn full_ft_loss_decreases_over_10_steps() {
        let Some(be) = pjrt() else { return };
        let cfg = RunConfig {
            executable: "train_step_chronicals".into(),
            steps: 10,
            warmup_steps: 0,
            lr: 5e-3,
            packed: true,
            corpus_examples: 256,
            ..RunConfig::default()
        };
        let s = harness::run_variant(&be, &cfg).unwrap();
        assert!(s.last_loss.is_finite());
        assert!(s.last_loss < s.first_loss, "loss {} -> {}", s.first_loss, s.last_loss);
        assert!(s.verification.is_training, "{:?}", s.verification.failures);
    }

    #[test]
    fn broken_variant_flagged_by_verifier() {
        let Some(be) = pjrt() else { return };
        let cfg = RunConfig {
            executable: "train_step_lora_broken".into(),
            steps: 5,
            warmup_steps: 0,
            packed: true,
            corpus_examples: 128,
            ..RunConfig::default()
        };
        let s = harness::run_variant(&be, &cfg).unwrap();
        assert!(!s.verification.is_training);
        assert_eq!(s.verification.zero_grad_steps, 5);
    }

    #[test]
    fn checkpoint_roundtrip_from_device_state() {
        let Some(be) = pjrt() else { return };
        let init = chronicals::session::resolve_init(
            be.manifest(),
            "train_step_chronicals",
            "init_chronicals",
        )
        .unwrap();
        let state = be.init_state(&init, 11).unwrap();
        let tensors = be.state_params(&state).unwrap();
        let path = std::env::temp_dir().join("chronicals_pjrt_integration.ckpt");
        checkpoint::save(&path, &tensors, checkpoint::Codec::F32).unwrap();
        let back = checkpoint::load(&path).unwrap();
        assert_eq!(tensors.len(), back.len());
        for (a, b) in tensors.iter().zip(&back) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn kernel_microbenches_execute() {
        let Some(be) = pjrt() else { return };
        let rows = harness::kernel_microbench(be.as_ref(), 3).unwrap();
        assert_eq!(rows.len(), 7);
        for (name, fused, naive) in rows {
            assert!(fused > 0.0 && naive > 0.0, "{name}");
        }
    }
}
