//! Property tests for batch construction invariants (paper Alg. 17,
//! Def. 33): randomized example sets through `packing_to_batches` and
//! `token_budget_batches` must always produce structurally sound [B, S]
//! tensors. Exercises the truncation path (`rust/src/batching/mod.rs`,
//! `token_budget_batches` flush) with examples longer than `seq`.

use chronicals::batching::{packing_to_batches, token_budget_batches, Batch};
use chronicals::data::TokenizedExample;
use chronicals::packing::{best_fit_decreasing, first_fit_decreasing, next_fit, Packing};
use chronicals::util::rng::Rng;

/// Random examples with the data pipeline's conventions: tokens ≥ 4 (ids
/// 0–3 are specials), next-token targets with a masked prompt prefix, and
/// the final position always masked.
fn gen_examples(rng: &mut Rng, n: usize, max_len: usize) -> Vec<TokenizedExample> {
    (0..n)
        .map(|_| {
            let len = rng.range(1, max_len + 1);
            let tokens: Vec<i32> = (0..len).map(|_| rng.range(4, 64) as i32).collect();
            let mask_prefix = rng.range(0, len); // prompt-style masking
            let mut targets = vec![-1i32; len];
            for i in 0..len.saturating_sub(1) {
                if i >= mask_prefix {
                    targets[i] = tokens[i + 1];
                }
            }
            TokenizedExample { tokens, targets }
        })
        .collect()
}

/// Check every structural invariant of one emitted batch.
fn check_batch(b: &Batch, seq: usize) {
    assert_eq!(b.seq, seq);
    let n = b.batch * b.seq;
    let tokens = b.tokens.as_i32().unwrap();
    let targets = b.targets.as_i32().unwrap();
    let segs = b.seg_ids.as_i32().unwrap();
    let pos = b.pos_ids.as_i32().unwrap();
    assert_eq!(tokens.len(), n);
    assert_eq!(targets.len(), n);
    assert_eq!(segs.len(), n);
    assert_eq!(pos.len(), n);
    assert_eq!(b.tokens.shape(), &[b.batch, b.seq]);

    let mut real_tokens = 0usize;
    let mut real_targets = 0usize;
    for row in 0..b.batch {
        let r = row * seq;
        let mut prev_seg = 0i32;
        let mut padding_started = false;
        let mut row_tokens = 0usize;
        for i in 0..seq {
            let s = segs[r + i];
            assert!(s >= 0, "negative segment id");
            if s == 0 {
                // 0 = padding; once padding starts it runs to the row end
                padding_started = true;
                assert_eq!(tokens[r + i], 0, "padding slot carries a token");
                assert_eq!(targets[r + i], -1, "padding slot carries a target");
                continue;
            }
            assert!(!padding_started, "segment {s} after padding in row {row}");
            row_tokens += 1;
            if s == prev_seg {
                // inside a segment: positions increment by exactly 1
                assert_eq!(pos[r + i], pos[r + i - 1] + 1, "pos not contiguous");
            } else {
                // new segment: ids are 1, 2, ... in order; pos resets to 0
                assert_eq!(s, prev_seg + 1, "segment ids not monotone in row {row}");
                assert_eq!(pos[r + i], 0, "pos not reset at segment start");
            }
            // a segment's final position must never predict across the
            // boundary: the builder masks truncated boundaries, the data
            // pipeline masks natural ends
            let seg_ends = i + 1 == seq || segs[r + i + 1] != s;
            if seg_ends {
                assert_eq!(
                    targets[r + i],
                    -1,
                    "segment-final position supervised in row {row} at {i}"
                );
            }
            prev_seg = s;
        }
        assert!(row_tokens <= seq);
        real_tokens += row_tokens;
    }
    for &t in targets {
        if t >= 0 {
            real_targets += 1;
        }
    }
    assert_eq!(b.real_tokens, real_tokens, "real_tokens accounting");
    assert_eq!(b.real_targets, real_targets, "real_targets accounting");
}

#[test]
fn packing_to_batches_invariants_hold_for_all_algorithms() {
    let mut rng = Rng::new(0xBA7C4);
    for round in 0..40 {
        let seq = [8, 16, 32][rng.range(0, 3)];
        let batch = rng.range(1, 5);
        let n_examples = rng.range(2, 60);
        // lengths ≤ seq so no example is oversized for the packer
        let exs = gen_examples(&mut rng, n_examples, seq);
        let lengths: Vec<usize> = exs.iter().map(|e| e.len()).collect();
        let packings: Vec<Packing> = vec![
            best_fit_decreasing(&lengths, seq),
            first_fit_decreasing(&lengths, seq),
            next_fit(&lengths, seq),
        ];
        for p in &packings {
            let batches = packing_to_batches(p, &exs, batch, seq);
            let total_available: usize = lengths.iter().sum();
            let mut total_emitted = 0usize;
            for b in &batches {
                assert_eq!(b.batch, batch, "round {round}");
                check_batch(b, seq);
                total_emitted += b.real_tokens;
            }
            // incomplete trailing batches are dropped, never padded up
            assert!(total_emitted <= total_available, "round {round}");
        }
    }
}

#[test]
fn token_budget_batches_invariants_and_conservation() {
    let mut rng = Rng::new(0x70B0D);
    for round in 0..40 {
        let seq = [8, 16, 32][rng.range(0, 3)];
        let budget = seq * rng.range(2, 6);
        let n_examples = rng.range(2, 60);
        // up to 2·seq: exercises the truncation path for oversized examples
        let exs = gen_examples(&mut rng, n_examples, seq * 2);
        let batches = token_budget_batches(&exs, budget, seq);
        let rows_per_batch = budget.div_ceil(seq);
        let mut total = 0usize;
        for b in &batches {
            assert_eq!(b.batch, rows_per_batch, "round {round}");
            check_batch(b, seq);
            assert!(
                b.real_tokens <= budget,
                "round {round}: batch carries {} > budget {budget}",
                b.real_tokens
            );
            total += b.real_tokens;
        }
        // every example contributes exactly min(len, seq) tokens: nothing
        // is dropped, truncation only clips at the row capacity
        let expected: usize = exs.iter().map(|e| e.len().min(seq)).sum();
        assert_eq!(total, expected, "round {round}: token conservation");
    }
}

#[test]
fn token_budget_truncated_example_masks_boundary() {
    // one example twice the row capacity: the final kept position must be
    // masked (it would otherwise predict a clipped-off token)
    let tokens: Vec<i32> = (4..20).collect(); // len 16
    let mut targets: Vec<i32> = tokens[1..].to_vec();
    targets.push(-1);
    let exs = vec![TokenizedExample { tokens, targets }];
    let batches = token_budget_batches(&exs, 8, 8);
    assert_eq!(batches.len(), 1);
    let b = &batches[0];
    assert_eq!(b.real_tokens, 8);
    let tg = b.targets.as_i32().unwrap();
    assert_eq!(tg[7], -1, "truncated boundary must be masked");
    check_batch(b, 8);
}

#[test]
fn single_token_examples_pack_cleanly() {
    // degenerate lengths stress the seg/pos bookkeeping: every segment is
    // one token long, so every position is both a start (pos 0) and an end
    // (target -1)
    let exs: Vec<TokenizedExample> = (0..12)
        .map(|i| TokenizedExample { tokens: vec![4 + i], targets: vec![-1] })
        .collect();
    let lengths = vec![1usize; 12];
    let p = best_fit_decreasing(&lengths, 4);
    let batches = packing_to_batches(&p, &exs, 1, 4);
    assert!(!batches.is_empty());
    for b in &batches {
        check_batch(b, 4);
        assert_eq!(b.real_targets, 0);
    }
}
