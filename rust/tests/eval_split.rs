//! Property tests for the held-out eval split (DESIGN.md §9).
//!
//! The split contract: `eval_split(n, fraction, seed)` partitions `0..n`
//! into disjoint train/eval index sets, holds out ⌊n·fraction⌋ examples
//! (clamped so both sides stay non-empty), is a pure function of
//! `(n, fraction, seed)` — bitwise stable across calls and indifferent to
//! shuffle seeds or epoch counts — and rejects nonsense fractions at
//! session build time with actionable messages.

use chronicals::backend::cpu::CpuBackend;
use chronicals::session::{eval_split, DataSource, RunReport, SessionBuilder, Task};
use std::sync::Arc;

#[test]
fn split_partitions_every_shape() {
    for &(n, f) in &[(2, 0.5), (5, 0.9), (10, 0.01), (10, 0.2), (97, 0.33), (100, 0.2)] {
        for seed in [0u64, 1, 42, u64::MAX] {
            let (train, eval) = eval_split(n, f, seed);
            // sizes: ⌊n·f⌋ clamped to [1, n-1], nothing lost
            let expect_eval = ((n as f64 * f).floor() as usize).clamp(1, n - 1);
            assert_eq!(eval.len(), expect_eval, "n={n} f={f} seed={seed}");
            assert_eq!(train.len() + eval.len(), n);
            // disjoint, and the union is exactly 0..n
            let mut union: Vec<usize> = train.iter().chain(&eval).copied().collect();
            union.sort_unstable();
            assert_eq!(union, (0..n).collect::<Vec<_>>(), "n={n} f={f} seed={seed}");
            // both sides come back sorted (stable downstream iteration)
            assert!(train.windows(2).all(|w| w[0] < w[1]));
            assert!(eval.windows(2).all(|w| w[0] < w[1]));
        }
    }
}

#[test]
fn split_is_bitwise_stable_and_seed_driven() {
    let a = eval_split(100, 0.2, 7);
    let b = eval_split(100, 0.2, 7);
    assert_eq!(a, b, "same (n, fraction, seed) must reproduce the same split");
    let c = eval_split(100, 0.2, 8);
    assert_ne!(a.1, c.1, "a different seed must pick a different holdout");
    // the clamp keeps both sides alive at the extremes
    let (train, eval) = eval_split(2, 0.01, 3);
    assert_eq!((train.len(), eval.len()), (1, 1));
    let (train, eval) = eval_split(10, 0.99, 3);
    assert_eq!((train.len(), eval.len()), (1, 9));
}

fn run_with(shuffle_seed: Option<u64>, epochs: Option<u64>) -> RunReport {
    let mut b = SessionBuilder::new()
        .task(Task::FullFinetune)
        .data(DataSource::synthetic(64, 42, 48))
        .eval_fraction(0.25)
        .steps(4)
        .lr(1e-3)
        .seed(42)
        .on_backend(Arc::new(CpuBackend::new()));
    if let Some(s) = shuffle_seed {
        b = b.shuffle_seed(s);
    }
    if let Some(e) = epochs {
        b = b.epochs(e);
    }
    b.build().unwrap().run().unwrap()
}

#[test]
fn holdout_is_invariant_to_shuffle_and_epoch_settings() {
    // the split depends on the session seed alone: whatever the batch plan
    // does (cycle mode, shuffled epochs, more epochs), the held-out set —
    // and therefore the untrained step-0 eval loss — is bitwise identical
    let base = run_with(None, None);
    let shuffled = run_with(Some(9), Some(1));
    let two_epochs = run_with(Some(3), Some(2));

    assert_eq!(base.eval_examples, 16, "⌊64 · 0.25⌋ examples held out");
    assert_eq!(shuffled.eval_examples, 16);
    assert_eq!(two_epochs.eval_examples, 16);

    let step0 = |r: &RunReport| {
        assert_eq!(r.eval.first().map(|&(s, _)| s), Some(0), "eval starts before training");
        r.eval[0].1
    };
    let b0 = step0(&base);
    assert_eq!(b0.to_bits(), step0(&shuffled).to_bits(), "shuffle must not move the holdout");
    assert_eq!(b0.to_bits(), step0(&two_epochs).to_bits(), "epochs must not move the holdout");

    // the series covers the run: last point lands on the final step, the
    // summary echoes it, and training only ever saw the remaining examples
    for r in [&base, &shuffled, &two_epochs] {
        assert_eq!(r.examples, 64);
        assert_eq!(r.final_eval_loss, r.eval.last().map(|&(_, l)| l));
        assert!(r.eval.len() >= 2, "step-0 and final-step eval points: {:?}", r.eval);
    }
    assert_eq!(base.eval.last().unwrap().0, 4, "cycle mode evals at the last step");
}

#[test]
fn training_moves_the_eval_loss() {
    // held-out loss responds to training on this tiny substrate — the eval
    // pass reads real updated parameters, not a stale snapshot
    let r = run_with(None, None);
    let first = r.eval.first().unwrap().1;
    let last = r.final_eval_loss.unwrap();
    assert!(first.is_finite() && last.is_finite());
    assert_ne!(
        first.to_bits(),
        last.to_bits(),
        "4 optimizer steps must move the held-out loss ({first} -> {last})"
    );
}

#[test]
fn bad_fractions_are_rejected_at_build_with_real_messages() {
    let build = |f: f64| {
        SessionBuilder::new()
            .data(DataSource::synthetic(16, 1, 32))
            .eval_fraction(f)
            .build_spec()
    };
    for bad in [0.0, -0.25, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
        let err = build(bad).unwrap_err().to_string();
        assert!(err.contains("positive and finite"), "{bad}: {err}");
        assert!(err.contains("--eval-fraction"), "points at the flag: {err}");
    }
    for bad in [1.0, 1.5, 7.0] {
        let err = build(bad).unwrap_err().to_string();
        assert!(err.contains("at least one example trains"), "{bad}: {err}");
    }
    assert!(build(0.2).is_ok());
}
