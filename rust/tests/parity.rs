//! Cross-backend parity suite: `FastCpuBackend` (tiled/threaded fused
//! kernels) against `CpuBackend` (the bitwise-deterministic reference
//! oracle) through the public `Backend` API only.
//!
//! Tolerance policy (DESIGN.md §4.3): reassociation in the fast kernels
//! legitimately changes low-order bits, so parity is loss |Δ| ≤ 1e-4 and
//! grad-norm relative Δ ≤ 1e-3 per step over several steps — while the
//! fast backend itself must be bitwise deterministic run-to-run and across
//! thread counts.
//!
//! Also here: the online-softmax/tiled-logsumexp unit check against the
//! materialized reference. The allocation-accounting tests that prove the
//! fast path never materializes `[B, Hq, S, S]` or `[T, V]` — and that a
//! warm arena stops allocating — live in `no_materialization.rs`; the
//! counters are arena-local (one arena per backend), so they cannot race
//! against other tests that drive a fast backend concurrently.

use chronicals::backend::cpu::math;
use chronicals::backend::cpu::CpuBackend;
use chronicals::backend::cpu_fast::{cce, Exec, FastCpuBackend};
use chronicals::backend::{Backend, DeviceBatch, DeviceState};
use chronicals::batching::Batch;
use chronicals::harness;
use chronicals::util::rng::Rng;
use std::sync::Arc;

const LOSS_TOL: f32 = 1e-4;
const GRAD_NORM_REL_TOL: f32 = 1e-3;

/// Same corpus/batches for an executable on a backend's manifest.
fn batches_for(be: &dyn Backend, exe: &str, seed: u64) -> Vec<Batch> {
    let spec = be.manifest().get(exe).unwrap().clone();
    let (_tok, exs) = harness::build_corpus(192, seed, spec.model_config.vocab, 48);
    harness::make_batches(be.manifest(), exe, &exs, true).unwrap()
}

/// Drive `steps` steps of `exe` on one backend, returning per-step
/// (loss, grad_norm) plus the final parameters.
fn drive(
    be: &dyn Backend,
    exe: &str,
    init: &str,
    seed: i32,
    steps: u64,
    lr: f32,
    lr_b: f32,
) -> (Vec<(f32, f32)>, Vec<chronicals::runtime::HostTensor>) {
    let batches = batches_for(be, exe, seed as u64);
    let mut state = be.init_state(init, seed).unwrap();
    let ub = be.upload_batch(exe, &batches[0]).unwrap();
    let mut out = Vec::new();
    for step in 1..=steps {
        let o = be.train_step(exe, &mut state, &ub, step, lr, lr_b).unwrap();
        out.push((o.loss, o.grad_norm));
    }
    let params = be.state_params(&state).unwrap();
    (out, params)
}

fn assert_parity(reference: &[(f32, f32)], fast: &[(f32, f32)], what: &str) {
    assert_eq!(reference.len(), fast.len());
    for (i, ((rl, rg), (fl, fg))) in reference.iter().zip(fast).enumerate() {
        assert!(rl.is_finite() && fl.is_finite(), "{what} step {i}: non-finite loss");
        assert!(
            (rl - fl).abs() <= LOSS_TOL * (1.0 + rl.abs()),
            "{what} step {i}: loss {fl} vs reference {rl}"
        );
        assert!(*rg > 0.0, "{what} step {i}: reference grad_norm zero");
        let rel = (rg - fg).abs() / rg.max(1e-12);
        assert!(
            rel <= GRAD_NORM_REL_TOL,
            "{what} step {i}: grad_norm {fg} vs reference {rg} (rel {rel})"
        );
    }
}

#[test]
fn full_ft_parity_over_several_steps() {
    let reference = CpuBackend::new();
    let fast = FastCpuBackend::with_threads(3);
    let exe = "train_step_chronicals";
    let (r, rp) = drive(&reference, exe, "init_chronicals", 42, 6, 5e-3, 5e-3);
    let (f, fp) = drive(&fast, exe, "init_chronicals", 42, 6, 5e-3, 5e-3);
    assert_parity(&r, &f, "full_ft");
    // Per-parameter agreement after 6 AdamW steps. The bound is loose on
    // purpose: AdamW's sign-like first step means an element whose true
    // gradient is ~0 can flip sign between backends and drift by ~lr per
    // step — legitimate float divergence, not a bug. Layout mix-ups and
    // missing scale factors still blow far past this.
    assert_eq!(rp.len(), fp.len());
    for (ti, (a, b)) in rp.iter().zip(&fp).enumerate() {
        assert_eq!(a.shape(), b.shape(), "param {ti} shape");
        for (ei, (x, y)) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()).enumerate() {
            assert!((x - y).abs() < 0.05, "param {ti}[{ei}]: {x} vs {y}");
        }
    }
}

#[test]
fn lora_and_lora_plus_parity() {
    let reference = CpuBackend::new();
    let fast = FastCpuBackend::with_threads(2);
    for (label, lr_b_mul) in [("lora", 1.0f32), ("lora_plus(λ=16)", 16.0f32)] {
        let lr = 2e-3f32;
        let (r, _) = drive(&reference, "train_step_lora", "init_lora", 7, 6, lr, lr * lr_b_mul);
        let (f, _) = drive(&fast, "train_step_lora", "init_lora", 7, 6, lr, lr * lr_b_mul);
        assert_parity(&r, &f, label);
    }
}

#[test]
fn broken_mode_parity_zero_grad() {
    let reference = CpuBackend::new();
    let fast = FastCpuBackend::with_threads(2);
    let (r, _) = drive(&reference, "train_step_lora_broken", "init_lora", 3, 3, 1e-3, 1e-3);
    let (f, _) = drive(&fast, "train_step_lora_broken", "init_lora", 3, 3, 1e-3, 1e-3);
    for ((rl, rg), (fl, fg)) in r.iter().zip(&f) {
        assert_eq!(*rg, 0.0);
        assert_eq!(*fg, 0.0);
        assert!((rl - fl).abs() <= LOSS_TOL * (1.0 + rl.abs()), "{fl} vs {rl}");
    }
}

/// `threads = 1` must be fully single-threaded (zero pool workers) and
/// run-to-run deterministic; by construction the fast backend's bits are
/// also invariant to the thread count on the pooled path — assert both
/// across the satellite-required `CHRONICALS_THREADS ∈ {1, 2, 8}` ladder.
#[test]
fn pooled_steps_bitwise_identical_across_thread_counts() {
    let run = |threads: usize| {
        let fast = FastCpuBackend::with_threads(threads);
        let (steps, _) = drive(&fast, "train_step_chronicals", "init_chronicals", 11, 5, 5e-3, 5e-3);
        steps
            .iter()
            .map(|(l, g)| (l.to_bits(), g.to_bits()))
            .collect::<Vec<_>>()
    };
    let once = run(1);
    let again = run(1);
    assert_eq!(once, again, "threads=1 runs diverged");
    for threads in [2usize, 4, 8] {
        assert_eq!(once, run(threads), "threads={threads} changed the bits");
    }
}

/// The env-resolved backend (what CI's `CHRONICALS_THREADS` matrix
/// constructs) must produce the same bits as the explicit single-threaded
/// run — this is the test that makes the CI thread matrix meaningful.
#[test]
fn env_resolved_thread_count_keeps_bits() {
    let auto = FastCpuBackend::new(); // CHRONICALS_THREADS > autodetect
    let (a, _) = drive(&auto, "train_step_chronicals", "init_chronicals", 13, 4, 5e-3, 5e-3);
    let one = FastCpuBackend::with_threads(1);
    let (b, _) = drive(&one, "train_step_chronicals", "init_chronicals", 13, 4, 5e-3, 5e-3);
    let bits = |v: &[(f32, f32)]| v.iter().map(|(l, g)| (l.to_bits(), g.to_bits())).collect::<Vec<_>>();
    assert_eq!(
        bits(&a),
        bits(&b),
        "env-resolved thread count ({}) changed the bits vs threads=1",
        auto.threads()
    );
}

/// Online-softmax unit test: the tiled streaming logsumexp must match the
/// materialized softmax/logsumexp on random logits, including vocab sizes
/// that are not a multiple of the tile.
#[test]
fn tiled_logsumexp_matches_materialized_reference() {
    let (t, d) = (13usize, 8usize);
    for v in [32usize, 64, 77, 200] {
        let mut rng = Rng::new(v as u64);
        let hf: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32 * 1.5).collect();
        let w: Vec<f32> = (0..v * d).map(|_| rng.normal() as f32 * 0.4).collect();
        let targets: Vec<i32> =
            (0..t).map(|i| if i % 5 == 4 { -1 } else { rng.range(0, v) as i32 }).collect();

        // materialized reference: full [t, v] logits + softmax buffer
        let mut logits = vec![0.0f32; t * v];
        math::linear_fwd(&hf, &w, t, d, v, &mut logits);
        let mut probs = vec![0.0f32; t * v];
        let (want_loss, want_nv) = math::softmax_xent(&logits, &targets, t, v, &mut probs);

        let ex = Exec::new(2);
        let mut lse = vec![0.0f32; t];
        let (loss, nv) = cce::cce_loss_fwd(&hf, &w, &targets, t, d, v, &mut lse, &ex);
        assert_eq!(nv, want_nv, "v={v}");
        assert!(
            (loss - want_loss).abs() < 1e-4 * (1.0 + want_loss.abs()),
            "v={v}: {loss} vs {want_loss}"
        );
        // per-row logsumexp against the direct computation
        for ti in 0..t {
            if targets[ti] < 0 {
                continue;
            }
            let row = &logits[ti * v..(ti + 1) * v];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let direct = row.iter().map(|z| (z - m).exp()).sum::<f32>().ln() + m;
            assert!((lse[ti] - direct).abs() < 1e-4, "v={v} row {ti}");
        }
    }
}

/// Checkpoints are interchangeable: the two CPU backends share the state
/// layout, so params trained on the fast backend restore into the
/// reference backend and evaluate identically (same forward oracle).
#[test]
fn fast_checkpoint_restores_into_reference_backend() {
    let fast = FastCpuBackend::with_threads(2);
    let reference = CpuBackend::new();
    let exe = "train_step_chronicals";
    let batches = batches_for(&fast, exe, 21);
    let mut state = fast.init_state("init_chronicals", 21).unwrap();
    let ub = fast.upload_batch(exe, &batches[0]).unwrap();
    for step in 1..=4u64 {
        fast.train_step(exe, &mut state, &ub, step, 5e-3, 5e-3).unwrap();
    }
    let params = fast.state_params(&state).unwrap();
    let fast_eval = fast.eval_loss("eval_chronicals", &state, &batches[0]).unwrap();

    let mut ref_state = reference.init_state("init_chronicals", 999).unwrap();
    reference.load_params(&mut ref_state, &params).unwrap();
    let ref_eval = reference.eval_loss("eval_chronicals", &ref_state, &batches[0]).unwrap();
    assert!(
        (fast_eval - ref_eval).abs() < 1e-4 * (1.0 + ref_eval.abs()),
        "{fast_eval} vs {ref_eval}"
    );
}

/// The fast backend is as strict as the reference about geometry and
/// family mismatches (same guards, same error surface).
#[test]
fn fast_backend_rejects_mismatches_like_reference() {
    let fast = FastCpuBackend::with_threads(1);
    // wrong geometry refused at staging
    let exs = vec![chronicals::data::TokenizedExample {
        tokens: vec![4, 5, 6, 7],
        targets: vec![5, 6, 7, -1],
    }];
    let small = chronicals::batching::padded_batches(&exs, 1, 8).remove(0);
    assert!(fast.upload_batch("train_step_chronicals", &small).is_err());
    // family mismatch refused at step time
    let mut full_state = fast.init_state("init_chronicals", 1).unwrap();
    let batches = batches_for(&fast, "train_step_lora", 1);
    let ub = fast.upload_batch("train_step_lora", &batches[0]).unwrap();
    assert!(fast.train_step("train_step_lora", &mut full_state, &ub, 1, 1e-3, 1e-3).is_err());
}

/// The harness end-to-end path works on the fast backend through the same
/// `run_variant` workflow the CLI uses (trainer, verifier, metering).
#[test]
fn run_variant_trains_on_fast_backend() {
    let be: Arc<dyn Backend> = Arc::new(FastCpuBackend::with_threads(2));
    let cfg = chronicals::config::RunConfig {
        executable: "train_step_chronicals".into(),
        steps: 10,
        warmup_steps: 0,
        lr: 5e-3,
        packed: true,
        corpus_examples: 192,
        max_seq: 48,
        ..chronicals::config::RunConfig::default()
    };
    let s = harness::run_variant(&be, &cfg).unwrap();
    assert!(s.verification.is_training, "{:?}", s.verification.failures);
    assert!(s.last_loss < s.first_loss, "{} -> {}", s.first_loss, s.last_loss);
}

/// Held-out eval parity: the same session spec (synthetic corpus, 25%
/// eval split) run on both CPU backends must agree on every point of the
/// eval-loss series within the loss tolerance — same split (seeded), same
/// batches, reassociation-only differences in the forward pass.
#[test]
fn session_eval_series_parity() {
    let run = |be: Arc<dyn Backend>| {
        chronicals::session::SessionBuilder::new()
            .data(chronicals::session::DataSource::synthetic(64, 42, 48))
            .eval_fraction(0.25)
            .steps(4)
            .lr(5e-3)
            .seed(42)
            .on_backend(be)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let r = run(Arc::new(CpuBackend::new()));
    let f = run(Arc::new(FastCpuBackend::with_threads(3)));
    assert_eq!(r.eval_examples, 16);
    assert_eq!(f.eval_examples, 16, "split must not depend on the backend");
    assert_eq!(r.eval.len(), f.eval.len());
    for ((rs, rl), (fs, fl)) in r.eval.iter().zip(&f.eval) {
        assert_eq!(rs, fs, "eval step points must line up");
        assert!(rl.is_finite() && fl.is_finite(), "step {rs}: non-finite eval loss");
        assert!(
            (rl - fl).abs() <= LOSS_TOL * (1.0 + rl.abs()),
            "step {rs}: eval loss {fl} vs reference {rl}"
        );
    }
    let (rf, ff) = (r.final_eval_loss.unwrap(), f.final_eval_loss.unwrap());
    assert!((rf - ff).abs() <= LOSS_TOL * (1.0 + rf.abs()), "final {ff} vs {rf}");
}

/// Drive a full session at a given data-parallel worker count and return
/// the bit patterns of everything the RunReport exposes as a series:
/// per-step (loss, grad_norm) and the held-out eval-loss series.
fn dp_session_bits(workers: usize, threads: usize) -> (Vec<(u32, u32)>, Vec<(u64, u32)>) {
    let mut session = chronicals::session::SessionBuilder::new()
        .data(chronicals::session::DataSource::synthetic(64, 42, 48))
        .eval_fraction(0.25)
        .steps(5)
        .lr(5e-3)
        .seed(42)
        .backend(chronicals::session::BackendSpec::CpuFast { threads })
        .workers(workers)
        .build()
        .unwrap();
    let report = session.run().unwrap();
    let steps = session
        .records()
        .iter()
        .map(|r| (r.loss.to_bits(), r.grad_norm.to_bits()))
        .collect();
    let eval = report.eval.iter().map(|(s, l)| (*s, l.to_bits())).collect();
    (steps, eval)
}

/// The tentpole contract: `--workers N` for N ∈ {1, 2, 4} produces
/// bitwise-identical loss, grad-norm and eval series. Worker count only
/// changes which replica computes which row — every batch decomposes into
/// the same per-row gradient tasks and the same fixed-order reduction
/// tree regardless of N (DESIGN.md §10).
#[test]
fn workers_ladder_bitwise_identical() {
    let one = dp_session_bits(1, 2);
    assert!(!one.0.is_empty() && !one.1.is_empty());
    for workers in [2usize, 4] {
        assert_eq!(one, dp_session_bits(workers, 2), "workers={workers} changed the bits");
    }
}

/// The worker ladder composes with the PR-4 thread ladder: neither the
/// replica count nor each replica's pool width may touch the bits.
#[test]
fn worker_and_thread_ladders_compose() {
    let base = dp_session_bits(2, 1);
    assert_eq!(base, dp_session_bits(2, 4), "threads=4 changed the bits at workers=2");
    assert_eq!(base, dp_session_bits(4, 1), "workers=4 changed the bits at threads=1");
}

/// The data-parallel path is the same mathematics as the legacy
/// single-backend step — per-row forward/backward with the global loss
/// normalizer, tree-reduced — so DP(1) must match the legacy path within
/// the standard reassociation tolerance (it is NOT required to be
/// bitwise equal: the reduction tree sums row gradients in a different
/// association order than the batched backward).
#[test]
fn data_parallel_matches_legacy_within_tolerance() {
    let run = |workers: usize| {
        let mut session = chronicals::session::SessionBuilder::new()
            .data(chronicals::session::DataSource::synthetic(64, 42, 48))
            .steps(5)
            .lr(5e-3)
            .seed(42)
            .backend(chronicals::session::BackendSpec::Cpu)
            .workers(workers)
            .build()
            .unwrap();
        session.run().unwrap();
        session
            .records()
            .iter()
            .map(|r| (r.loss, r.grad_norm))
            .collect::<Vec<_>>()
    };
    let legacy = {
        let mut session = chronicals::session::SessionBuilder::new()
            .data(chronicals::session::DataSource::synthetic(64, 42, 48))
            .steps(5)
            .lr(5e-3)
            .seed(42)
            .backend(chronicals::session::BackendSpec::Cpu)
            .build()
            .unwrap();
        session.run().unwrap();
        session
            .records()
            .iter()
            .map(|r| (r.loss, r.grad_norm))
            .collect::<Vec<_>>()
    };
    assert_parity(&legacy, &run(1), "dp(1) vs legacy");
    assert_parity(&legacy, &run(2), "dp(2) vs legacy");
}

/// Property test for the shard seam: splitting a packed batch across any
/// worker count preserves the real-token and supervised-target multisets
/// and the row/accounting totals — sharding moves rows, never edits them.
#[test]
fn shard_splitting_preserves_token_and_target_multiset() {
    let reference = CpuBackend::new();
    let batches = batches_for(&reference, "train_step_chronicals", 5);
    assert!(!batches.is_empty());
    let real = |b: &Batch| -> (Vec<i32>, Vec<i32>) {
        let toks = b.tokens.as_i32().unwrap();
        let segs = b.seg_ids.as_i32().unwrap();
        let tgts = b.targets.as_i32().unwrap();
        let mut t: Vec<i32> = toks
            .iter()
            .zip(segs)
            .filter(|(_, &s)| s != 0)
            .map(|(&x, _)| x)
            .collect();
        let mut g: Vec<i32> = tgts.iter().filter(|&&x| x >= 0).copied().collect();
        t.sort_unstable();
        g.sort_unstable();
        (t, g)
    };
    for b in &batches {
        let want = real(b);
        for workers in 1..=b.batch + 2 {
            let shards = b.shard(workers).unwrap();
            assert!(shards.len() <= workers.min(b.batch));
            let (mut toks, mut tgts) = (Vec::new(), Vec::new());
            let (mut rows, mut rt, mut rg) = (0usize, 0usize, 0usize);
            for s in &shards {
                let (t, g) = real(s);
                toks.extend(t);
                tgts.extend(g);
                rows += s.batch;
                rt += s.real_tokens;
                rg += s.real_targets;
            }
            toks.sort_unstable();
            tgts.sort_unstable();
            assert_eq!(toks, want.0, "workers={workers}: token multiset changed");
            assert_eq!(tgts, want.1, "workers={workers}: target multiset changed");
            assert_eq!(rows, b.batch, "workers={workers}: rows lost");
            assert_eq!(rt, b.real_tokens, "workers={workers}: real_tokens accounting");
            assert_eq!(rg, b.real_targets, "workers={workers}: real_targets accounting");
        }
    }
}

// ---------------------------------------------------------------------------
// Memory-tier tolerance policy (DESIGN.md §12). Three tiers, each pinned
// by a test below:
//
// * **Tier A — bitwise.** fp32 optimizer states + dense base weights must
//   be bit-identical to the legacy (pre-tier) path, with or without
//   activation checkpointing: `--ckpt-segments N` only changes *when*
//   activations are computed (recompute replays the same kernels on the
//   same inputs in the same order), never a single bit of the result.
// * **Tier B — quantized-base drift.** `--base-quant int8` perturbs every
//   frozen weight coherently (a real quantization of the base), so the
//   run is compared to the dense run within per-step loss relative error
//   ≤ 1e-3. Gradient norms see the perturbation amplified through the
//   backward chain; their documented bound is relative error ≤ 1e-2.
// * **Tier C — quantized-optimizer drift.** `--optim-states int8`
//   round-trips the AdamW moments through Kahan-compensated int8 blocks
//   every step; the error accumulates across steps, so the bound is on
//   the end-to-end trajectory: every point of the held-out eval-loss
//   series over a 20-step run stays within |Δ| ≤ 0.05 of the fp32 run
//   (step 1 is bitwise — fresh slots decode to exact zero).
// ---------------------------------------------------------------------------

/// Drive a session with the given memory tiers; return per-step
/// (loss, grad_norm) plus the eval series.
fn tier_session(
    threads: usize,
    workers: usize,
    steps: u64,
    optim: chronicals::quant::OptimStates,
    base: Option<chronicals::quant::BaseQuant>,
    ckpt: usize,
) -> (Vec<(f32, f32)>, Vec<(u64, f32)>) {
    let mut b = chronicals::session::SessionBuilder::new()
        .task(chronicals::session::Task::lora())
        .data(chronicals::session::DataSource::synthetic(64, 42, 48))
        .eval_fraction(0.25)
        .steps(steps)
        .lr(2e-3)
        .seed(42)
        .backend(chronicals::session::BackendSpec::CpuFast { threads })
        .workers(workers)
        .optim_states(optim)
        .ckpt_segments(ckpt);
    if let Some(q) = base {
        b = b.base_quant(q);
    }
    let mut session = b.build().unwrap();
    let report = session.run().unwrap();
    let steps = session.records().iter().map(|r| (r.loss, r.grad_norm)).collect();
    (steps, report.eval)
}

fn tier_bits(run: &(Vec<(f32, f32)>, Vec<(u64, f32)>)) -> (Vec<(u32, u32)>, Vec<(u64, u32)>) {
    (
        run.0.iter().map(|(l, g)| (l.to_bits(), g.to_bits())).collect(),
        run.1.iter().map(|(s, l)| (*s, l.to_bits())).collect(),
    )
}

use chronicals::quant::{BaseQuant, OptimStates};

/// Tier A: fp32/dense checkpointed runs are bitwise identical to the
/// legacy path for every segment count.
#[test]
fn tier_a_checkpointing_is_bitwise_against_legacy() {
    let legacy = tier_bits(&tier_session(2, 0, 6, OptimStates::Fp32, None, 0));
    assert!(!legacy.0.is_empty());
    for segs in [1usize, 2] {
        let ckpt = tier_bits(&tier_session(2, 0, 6, OptimStates::Fp32, None, segs));
        assert_eq!(legacy, ckpt, "ckpt_segments={segs} changed the bits");
    }
}

/// Tier B: int8-quantized frozen base tracks the dense run within the
/// documented per-step bounds (loss rel ≤ 1e-3, grad-norm rel ≤ 1e-2)
/// while still training.
#[test]
fn tier_b_int8_base_tracks_dense_within_rel_bounds() {
    let (dense, _) = tier_session(2, 0, 8, OptimStates::Fp32, None, 0);
    let (quant, _) = tier_session(2, 0, 8, OptimStates::Fp32, Some(BaseQuant::Int8), 0);
    assert_eq!(dense.len(), quant.len());
    for (i, ((dl, dg), (ql, qg))) in dense.iter().zip(&quant).enumerate() {
        assert!(dl.is_finite() && ql.is_finite(), "step {i}: non-finite loss");
        let loss_rel = (dl - ql).abs() / dl.abs().max(1e-12);
        assert!(loss_rel <= 1e-3, "step {i}: loss {ql} vs dense {dl} (rel {loss_rel})");
        assert!(*qg > 0.0, "step {i}: quantized run stopped training");
        let g_rel = (dg - qg).abs() / dg.max(1e-12);
        assert!(g_rel <= 1e-2, "step {i}: grad_norm {qg} vs dense {dg} (rel {g_rel})");
    }
    let (first, last) = (quant.first().unwrap().0, quant.last().unwrap().0);
    assert!(last < first, "quantized-base run must still learn: {first} -> {last}");
}

/// Tier C: int8 optimizer states — every eval point of a 20-step run
/// stays within |Δ| ≤ 0.05 of the fp32 trajectory, and the first step is
/// bitwise (fresh slots decode to exact zero).
#[test]
fn tier_c_int8_optim_eval_series_drift_bounded_over_20_steps() {
    let fp32 = tier_session(2, 0, 20, OptimStates::Fp32, None, 0);
    let int8 = tier_session(2, 0, 20, OptimStates::Int8, None, 0);
    assert_eq!(fp32.0[0].0.to_bits(), int8.0[0].0.to_bits(), "step 1 must be bitwise");
    assert_eq!(fp32.0[0].1.to_bits(), int8.0[0].1.to_bits(), "step 1 must be bitwise");
    assert_eq!(fp32.1.len(), int8.1.len());
    assert!(fp32.1.last().unwrap().0 == 20, "eval series must span the run");
    for ((fs, fl), (is_, il)) in fp32.1.iter().zip(&int8.1) {
        assert_eq!(fs, is_, "eval step points must line up");
        assert!(
            (fl - il).abs() <= 0.05,
            "eval step {fs}: int8-optim loss {il} drifted from fp32 {fl}"
        );
    }
    // and the run itself still trains
    assert!(int8.0.last().unwrap().0 < int8.0.first().unwrap().0);
}

/// Determinism ladder, quantized rungs: the full three-tier configuration
/// (int8 optimizer states + int8 base + 2 checkpoint segments) is bitwise
/// invariant across `CHRONICALS_THREADS ∈ {1, 2, 8}`.
#[test]
fn quantized_tiers_bitwise_across_thread_ladder() {
    let one = tier_bits(&tier_session(
        1, 0, 5, OptimStates::Int8, Some(BaseQuant::Int8), 2,
    ));
    assert!(!one.0.is_empty() && !one.1.is_empty());
    for threads in [2usize, 8] {
        let t = tier_bits(&tier_session(
            threads, 0, 5, OptimStates::Int8, Some(BaseQuant::Int8), 2,
        ));
        assert_eq!(one, t, "threads={threads} changed the quantized bits");
    }
}

/// Determinism ladder, quantized rungs: the quantized configuration is
/// bitwise invariant across `--workers ∈ {1, 2, 4}` — sharding moves row
/// gradients, the int8 decode-update-encode runs once on the reduced
/// gradient either way.
#[test]
fn quantized_tiers_bitwise_across_worker_ladder() {
    let one = tier_bits(&tier_session(
        2, 1, 5, OptimStates::Int8, Some(BaseQuant::Int8), 0,
    ));
    assert!(!one.0.is_empty() && !one.1.is_empty());
    for workers in [2usize, 4] {
        let w = tier_bits(&tier_session(
            2, workers, 5, OptimStates::Int8, Some(BaseQuant::Int8), 0,
        ));
        assert_eq!(one, w, "workers={workers} changed the quantized bits");
    }
}

/// DeviceState/DeviceBatch created by one CPU backend are accepted by the
/// other (shared representation) — documented contract, pinned here.
#[test]
fn cpu_device_handles_are_shared_representation() {
    let fast = FastCpuBackend::with_threads(1);
    let reference = CpuBackend::new();
    let state = fast.init_state("init_chronicals", 2).unwrap();
    match &state {
        DeviceState::Cpu(_) => {}
        #[allow(unreachable_patterns)]
        _ => panic!("fast backend must produce DeviceState::Cpu"),
    }
    let batches = batches_for(&reference, "train_step_chronicals", 2);
    let ub = reference.upload_batch("train_step_chronicals", &batches[0]).unwrap();
    match &ub {
        DeviceBatch::Cpu(_) => {}
        #[allow(unreachable_patterns)]
        _ => panic!("reference backend must produce DeviceBatch::Cpu"),
    }
}
