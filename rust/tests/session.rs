//! Integration tests for the typed Session API (ISSUE 3):
//!
//! * the acceptance gate: `--task lora-plus --packing bfd` and the legacy
//!   `--executable train_step_lora` escape hatch produce bitwise-identical
//!   summaries on the CPU reference backend,
//! * `RunConfig` (TOML + every preset) → `SessionSpec` lowering,
//! * `BatchStream` vs the materialized helpers on a real corpus,
//! * build-time validation against a backend manifest,
//! * run-report data accounting (padded tail, oversized drops, cycling).

use chronicals::backend::cpu::CpuBackend;
use chronicals::backend::Backend;
use chronicals::batching::{
    packed_batches, padded_batches, BatchStream, PackingStrategy, TailPolicy,
};
use chronicals::config::RunConfig;
use chronicals::harness;
use chronicals::session::{
    BackendSpec, DataSource, Schedule, SessionBuilder, SessionSpec, Task,
};
use std::sync::Arc;

fn cpu() -> Arc<dyn Backend> {
    Arc::new(CpuBackend::new())
}

/// The ISSUE acceptance criterion: the typed task surface and the
/// stringly escape hatch must be the same run, bit for bit.
#[test]
fn typed_task_and_executable_escape_hatch_are_bitwise_identical() {
    let be = cpu();

    // `chronicals train --task lora-plus --packing bfd`
    let mut typed = SessionBuilder::new()
        .task(Task::lora_plus(16.0))
        .packing(PackingStrategy::Bfd)
        .steps(8)
        .lr(1e-3)
        .seed(11)
        .data(DataSource::synthetic(192, 11, 48))
        .on_backend(be.clone())
        .build()
        .unwrap();
    let t = typed.run().unwrap().summary;

    // `chronicals train --executable train_step_lora --lora-plus-ratio 16`
    let cfg = RunConfig {
        executable: "train_step_lora".into(),
        lora_plus_ratio: 16.0,
        packed: true,
        steps: 8,
        lr: 1e-3,
        seed: 11,
        corpus_examples: 192,
        max_seq: 48,
        warmup_steps: 3,
        ..RunConfig::default()
    };
    let e = harness::run_variant(&be, &cfg).unwrap();

    assert_eq!(t.first_loss.to_bits(), e.first_loss.to_bits());
    assert_eq!(t.last_loss.to_bits(), e.last_loss.to_bits());
    assert_eq!(
        t.verification.min_grad_norm.to_bits(),
        e.verification.min_grad_norm.to_bits()
    );
    assert_eq!(
        t.verification.max_grad_norm.to_bits(),
        e.verification.max_grad_norm.to_bits()
    );
    assert!(t.verification.is_training && e.verification.is_training);
}

#[test]
fn presets_lower_to_typed_specs() {
    let full = SessionSpec::from_run_config(&RunConfig::preset("full_ft").unwrap()).unwrap();
    assert_eq!(full.task, Task::FullFinetune);
    assert_eq!(full.packing, PackingStrategy::Bfd);
    assert_eq!(full.schedule, Schedule::Constant);
    assert_eq!(full.backend, BackendSpec::Cpu);

    let lora = SessionSpec::from_run_config(&RunConfig::preset("lora").unwrap()).unwrap();
    assert_eq!(lora.task, Task::Lora { rank: None });

    let lp = SessionSpec::from_run_config(&RunConfig::preset("lora_plus").unwrap()).unwrap();
    assert_eq!(lp.task, Task::LoraPlus { rank: None, ratio: 16.0 });

    let e2e = SessionSpec::from_run_config(&RunConfig::preset("e2e").unwrap()).unwrap();
    assert_eq!(e2e.schedule, Schedule::WarmupCosine { warmup: 10 });
    assert_eq!(e2e.steps, 300);
    match &e2e.task {
        Task::Custom { executable, init, lora_plus_ratio } => {
            assert_eq!(executable, "train_step_e2e");
            assert!(init.is_none());
            assert_eq!(*lora_plus_ratio, 1.0);
        }
        other => panic!("e2e should lower to Custom, got {other:?}"),
    }
}

#[test]
fn toml_lowers_to_typed_spec() {
    let cfg = RunConfig::from_toml(
        r#"
[train]
executable = "train_step_lora"
steps = 25
[data]
packed = false
corpus_examples = 512
max_seq = 256
[optim]
lr = 1e-3
lora_plus_ratio = 16.0
lr_schedule = "warmup_cosine"
lr_warmup_steps = 5
"#,
    )
    .unwrap();
    let spec = SessionSpec::from_run_config(&cfg).unwrap();
    assert_eq!(spec.task, Task::LoraPlus { rank: None, ratio: 16.0 });
    assert_eq!(spec.packing, PackingStrategy::Padded);
    assert_eq!(spec.schedule, Schedule::WarmupCosine { warmup: 5 });
    assert_eq!(spec.steps, 25);
    assert_eq!(spec.lr, 1e-3);
    assert_eq!(spec.data, DataSource::synthetic(512, cfg.seed, 256));
}

#[test]
fn lowering_rejects_bad_strings_with_real_errors() {
    let bad_schedule = RunConfig { lr_schedule: "linear".into(), ..RunConfig::default() };
    let err = SessionSpec::from_run_config(&bad_schedule).unwrap_err();
    assert!(err.to_string().contains("lr_schedule"), "{err}");

    let bad_backend = RunConfig { backend: "tpu".into(), ..RunConfig::default() };
    let err = SessionSpec::from_run_config(&bad_backend).unwrap_err();
    assert!(err.to_string().contains("backend"), "{err}");
}

#[test]
fn unknown_executable_on_backend_is_a_build_error() {
    // the e2e-scale executable exists only in the PJRT artifact set — on
    // the CPU substrate it must fail at build(), naming the executable
    let err = SessionBuilder::new()
        .task(Task::custom("train_step_e2e"))
        .on_backend(cpu())
        .build()
        .map(|_| ())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("train_step_e2e"), "{msg}");
    assert!(msg.contains("not in manifest"), "{msg}");
}

#[test]
fn batch_stream_matches_materialized_helpers_on_real_corpus() {
    let (_tok, exs) = harness::build_corpus(256, 9, 64, 48);
    for (strategy, eager) in [
        (PackingStrategy::Bfd, packed_batches(&exs, 4, 64)),
        (PackingStrategy::Padded, padded_batches(&exs, 4, 64)),
    ] {
        let streamed: Vec<_> =
            BatchStream::new(exs.clone(), strategy, 4, 64, TailPolicy::Drop).collect();
        assert_eq!(streamed.len(), eager.len(), "{strategy:?}");
        for (a, b) in streamed.iter().zip(&eager) {
            assert_eq!(a.tokens, b.tokens, "{strategy:?}: identical tensors, identical order");
            assert_eq!(a.targets, b.targets);
            assert_eq!(a.seg_ids, b.seg_ids);
            assert_eq!(a.pos_ids, b.pos_ids);
            assert_eq!(a.real_tokens, b.real_tokens);
            assert_eq!(a.real_targets, b.real_targets);
        }
    }
}

#[test]
fn session_reports_data_accounting_and_cycles_staged_batches() {
    let mut session = SessionBuilder::new()
        .task(Task::FullFinetune)
        .steps(40) // more steps than batches → the stream cycles
        .lr(5e-3)
        .data(DataSource::synthetic(64, 3, 48))
        .on_backend(cpu())
        .build()
        .unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.summary.steps, 40);
    assert_eq!(report.examples, 64);
    assert!(report.batches_planned >= 1);
    assert!(report.batches_staged <= report.batches_planned);
    assert!(
        report.batches_staged < 40,
        "tiny corpus must be staged once and cycled, not re-planned"
    );
    assert_eq!(report.oversized_dropped, 0, "48-token examples fit 64-token rows");
    assert!(report.summary.verification.is_training);
}

#[test]
fn oversized_examples_surface_in_the_report() {
    // max_seq 96 exceeds the 64-token row capacity: the BFD plan must skip
    // those examples *and say so* instead of losing them silently
    let mut session = SessionBuilder::new()
        .task(Task::FullFinetune)
        .steps(4)
        .lr(5e-3)
        .data(DataSource::synthetic(256, 5, 96))
        .on_backend(cpu())
        .build()
        .unwrap();
    let report = session.run().unwrap();
    assert!(
        report.oversized_dropped > 0,
        "a 96-token-truncated corpus must contain >64-token examples"
    );
}

#[test]
fn lora_plus_ratio_actually_changes_the_typed_run() {
    let run = |task: Task| {
        let mut s = SessionBuilder::new()
            .task(task)
            .steps(6)
            .lr(2e-3)
            .seed(4)
            .data(DataSource::synthetic(128, 4, 48))
            .on_backend(cpu())
            .build()
            .unwrap();
        s.run().unwrap().summary
    };
    let lora = run(Task::lora());
    let plus = run(Task::lora_plus(16.0));
    assert!(lora.verification.is_training && plus.verification.is_training);
    // identical data + seed, different λ ⇒ different trajectories
    assert_ne!(lora.last_loss.to_bits(), plus.last_loss.to_bits());
}
