//! Property tests for the packing substrate (hand-rolled generator — the
//! offline crate set has no proptest): BFD/FFD/NF invariants and the
//! paper's Thm. 8 bound across randomized instances.

use chronicals::packing::*;
use chronicals::util::rng::Rng;

/// Randomized instance generator: mixtures of uniform, log-normal and
/// adversarial near-capacity lengths.
fn random_instance(rng: &mut Rng, case: usize) -> (Vec<usize>, usize) {
    let capacity = [64usize, 128, 512, 2048][case % 4];
    let n = rng.range(1, 400);
    let lengths: Vec<usize> = (0..n)
        .map(|_| match case % 3 {
            0 => rng.range(1, capacity + capacity / 4), // some oversized
            1 => (rng.lognormal(4.0, 1.0) as usize).clamp(1, capacity),
            _ => {
                // adversarial: just over half capacity (pairs can't share)
                if rng.f64() < 0.5 {
                    capacity / 2 + rng.range(1, capacity / 4 + 1)
                } else {
                    rng.range(1, capacity / 3 + 1)
                }
            }
        })
        .collect();
    (lengths, capacity)
}

#[test]
fn prop_bfd_invariants_hold() {
    let mut rng = Rng::new(0xBFD);
    for case in 0..300 {
        let (lengths, capacity) = random_instance(&mut rng, case);
        let p = best_fit_decreasing(&lengths, capacity);
        validate(&p, &lengths).unwrap_or_else(|e| panic!("case {case}: {e}"));
    }
}

#[test]
fn prop_ffd_and_nf_invariants_hold() {
    let mut rng = Rng::new(0xFFD);
    for case in 0..200 {
        let (lengths, capacity) = random_instance(&mut rng, case);
        validate(&first_fit_decreasing(&lengths, capacity), &lengths).unwrap();
        validate(&next_fit(&lengths, capacity), &lengths).unwrap();
        validate(&no_packing(&lengths, capacity), &lengths).unwrap();
    }
}

#[test]
fn prop_bfd_within_theorem_bound() {
    // Thm. 8: BFD(I) <= 11/9 * OPT(I) + 6/9, with OPT >= ceil(sum/C).
    // (The bound vs the lower bound is implied by the bound vs OPT.)
    let mut rng = Rng::new(0x119);
    for case in 0..300 {
        let (lengths, capacity) = random_instance(&mut rng, case);
        let fit: Vec<usize> = lengths
            .iter()
            .copied()
            .filter(|&l| l <= capacity)
            .collect();
        if fit.is_empty() {
            continue;
        }
        let p = best_fit_decreasing(&fit, capacity);
        // true OPT is NP-hard; use the stronger of the two lower bounds:
        // capacity bound and the count of items > C/2 (each needs a bin)
        let lb_cap = Packing::opt_lower_bound(&fit, capacity);
        let lb_large = fit.iter().filter(|&&l| l * 2 > capacity).count();
        let lb = lb_cap.max(lb_large);
        assert!(
            p.n_bins() as f64 <= 11.0 / 9.0 * lb as f64 + 6.0 / 9.0 + 1e-9
                // BFD can exceed the *lower bound* by more than the OPT
                // bound only when the lower bound is loose; allow the
                // classical absolute slack of 1 bin for tiny instances.
                || p.n_bins() <= lb + 1,
            "case {case}: bins={} lb={lb}",
            p.n_bins()
        );
    }
}

#[test]
fn prop_bfd_never_worse_than_ffd_plus_margin() {
    // BFD and FFD have the same worst-case ratio; empirically BFD ≤ FFD+1
    // on these distributions.
    let mut rng = Rng::new(0xABCD);
    for case in 0..200 {
        let (lengths, capacity) = random_instance(&mut rng, case);
        let bfd = best_fit_decreasing(&lengths, capacity).n_bins();
        let ffd = first_fit_decreasing(&lengths, capacity).n_bins();
        assert!(bfd <= ffd + 1, "case {case}: bfd={bfd} ffd={ffd}");
    }
}

#[test]
fn prop_sorted_descending_within_bins_total_preserved() {
    let mut rng = Rng::new(0x5157);
    for case in 0..200 {
        let (lengths, capacity) = random_instance(&mut rng, case);
        let p = best_fit_decreasing(&lengths, capacity);
        let packed_total: usize = p.total_packed();
        let expect: usize = lengths.iter().filter(|&&l| l <= capacity).sum();
        assert_eq!(packed_total, expect, "case {case}");
    }
}

#[test]
fn prop_efficiency_monotone_bfd_ge_nf() {
    let mut rng = Rng::new(0xEFF);
    for case in 0..200 {
        let (lengths, capacity) = random_instance(&mut rng, case);
        let bfd = best_fit_decreasing(&lengths, capacity);
        let nf = next_fit(&lengths, capacity);
        assert!(
            bfd.efficiency() >= nf.efficiency() - 1e-9,
            "case {case}: bfd={} nf={}",
            bfd.efficiency(),
            nf.efficiency()
        );
    }
}
